"""Reuse-aware tuning: the census grid under an arm budget.

Where ``sweep_census.py`` runs a *fixed* batch the user picked up front,
this example hands the whole candidate grid to the
:class:`~repro.core.search.SearchDriver` with a budget of half the arms
and lets it choose. Before every submission the driver prices each
remaining candidate with the server's ``estimate`` RPC — compiled DAG
cost minus everything already materialized or in flight — and picks the
cheapest *marginal* arm. The result: it spends the budget
signature-adjacent (same ``reg``, different threshold), training half
the models a grid-order batch of equal size would.

Then a successive-halving run races four regularizations over a low
``train_iters`` rung, promotes the best two to full training through
the scheduler's rung priority, and early-stops the losers — whose
pins and ledger reservations are released immediately (the example
prints the ledger-vs-disk drift, which must be 0).

    PYTHONPATH=src:benchmarks python examples/tune_census.py

Env: HELIX_EXAMPLE_ROWS scales the dataset (default 30000; CI smoke
uses 2000).
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import workflows as W                                      # noqa: E402
from repro.core import StorageLedger                       # noqa: E402
from repro.core.config import EngineConfig                 # noqa: E402
from repro.core.search import (HalvingConfig, SearchConfig,  # noqa: E402
                               SearchDriver)
from repro.serve import SessionServer                      # noqa: E402

N_ROWS = int(os.environ.get("HELIX_EXAMPLE_ROWS", "30000"))


def main():
    base = W.CensusKnobs(n_rows=N_ROWS,
                         train_iters=max(30, N_ROWS // 100))
    registry = {"census": lambda **p:
                W.build_census(dataclasses.replace(base, **p))}
    space = [{"reg": r, "eval_threshold": t}
             for t in (0.5, 0.7) for r in (0.01, 0.03, 0.1, 0.3)]
    budget = len(space) // 2

    # --- budgeted search: the driver picks WHICH arms run ----------------
    with tempfile.TemporaryDirectory() as workdir:
        server = SessionServer(workdir, registry=registry,
                               engine=EngineConfig(n_sessions=1),
                               poll_interval=0.01)
        try:
            driver = SearchDriver(
                server, "census", space=space,
                config=SearchConfig(strategy="grid", max_arms=budget,
                                    frontier="reuse", max_inflight=2,
                                    metric="checkResults.value"))
            report = driver.run()
        finally:
            server.shutdown()

    print(f"grid of {len(space)} candidates, budget of {budget} arms:")
    for a in report.arms:
        if a.status == "skipped":
            continue
        est = a.estimate or {}
        print(f"  #{a.order} reg={a.params['reg']:<5} "
              f"thr={a.params['eval_threshold']:<4} "
              f"metric={a.metric if a.metric is not None else '-':<8} "
              f"est_marginal={est.get('marginal_s', float('nan')):.2f} "
              f"(hits={est.get('n_hit', 0)}, follow={est.get('n_follow', 0)})")
    n_models = len({a.params['reg'] for a in report.arms
                    if a.status != 'skipped'})
    print(f"distinct signatures computed: {len(report.fleet_computes())}"
          f"  models trained: {n_models} (grid order would train {budget})"
          f"  wasted recomputes: {report.wasted_recomputes()}")
    print(f"best: reg={report.best().params['reg']} "
          f"metric={report.best().metric:.3f}\n")

    # --- successive halving over train_iters ------------------------------
    with tempfile.TemporaryDirectory() as workdir:
        server = SessionServer(workdir, registry=registry,
                               engine=EngineConfig(n_sessions=2),
                               poll_interval=0.01)
        try:
            driver = SearchDriver(
                server, "census",
                space=[{"reg": r} for r in (0.01, 0.03, 0.1, 0.3)],
                config=SearchConfig(
                    strategy="grid", metric="checkResults.value",
                    max_inflight=2,
                    halving=HalvingConfig(
                        resource="train_iters",
                        levels=[max(10, base.train_iters // 5),
                                base.train_iters],
                        eta=2.0)))
            report = driver.run()
            drift = (StorageLedger(server.store.ledger_path).used()
                     - server.store.total_bytes())
        finally:
            server.shutdown()

    for rung in report.rungs:
        print(f"rung {rung['rung']} (train_iters={rung['level']}): "
              f"{rung['n_done']} ran, promoted {rung['promoted']}")
    best = report.best()
    print(f"halving best: reg={best.base_params['reg']} "
          f"metric={best.metric:.3f} at rung {best.rung}")
    print(f"ledger drift after early-stopped arms: {drift:.0f} B "
          f"(must be 0); wasted recomputes: {report.wasted_recomputes()}")


if __name__ == "__main__":
    main()
