"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch internlm2-1.8b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m  # SSM cache
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.launch import serve as serve_mod  # noqa: E402

if __name__ == "__main__":
    sys.argv[0] = "serve"
    serve_mod.main()
