"""The genomics workflow (paper Example 1): embed gene mentions, cluster,
iterate. Compares OPT vs NEVER-materialize cumulative time over 4 edits.

    PYTHONPATH=src:benchmarks python examples/genomics_iterate.py
"""
import dataclasses
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import workflows as W                            # noqa: E402
from repro.core import IterativeSession, Policy  # noqa: E402


def run(policy):
    base = dataclasses.replace(W.GenomicsKnobs(), n_docs=1500, emb_epochs=6)
    edits = [
        base,
        dataclasses.replace(base, n_clusters=32),     # L/I: cluster count
        dataclasses.replace(base, n_clusters=32, report_top=8),  # PPR
        dataclasses.replace(base, n_clusters=8),      # L/I again
    ]
    total = 0.0
    with tempfile.TemporaryDirectory() as workdir:
        sess = IterativeSession(workdir, policy=policy)
        for i, knobs in enumerate(edits):
            t0 = time.perf_counter()
            rep = sess.run(W.build_genomics(knobs))
            dt = time.perf_counter() - t0
            total += dt
            print(f"  [{policy.value}] iter {i}: {dt:6.2f}s  "
                  f"(computed {rep.execution.n_computed}, "
                  f"loaded {rep.execution.n_loaded}, "
                  f"pruned {rep.execution.n_pruned})  "
                  f"inertia={rep.outputs['clusterReport']['inertia']:.0f}")
    return total


def main():
    print("genomics workflow: 4 iterations (cluster-count + report edits)")
    t_nm = run(Policy.NEVER)
    t_opt = run(Policy.OPT)
    print(f"\ncumulative: NEVER={t_nm:.2f}s  OPT={t_opt:.2f}s  "
          f"speedup {t_nm / t_opt:.2f}x "
          f"(the expensive word2vec node is reused across edits)")


if __name__ == "__main__":
    main()
