"""Daily retrain on an append-mostly table: chunk-spliced recomputation.

The streaming pattern the chunked materializations unlock: a census
table grows by one day's batch of rows, and the retrain only pushes the
*new* chunk through the map-safe featurization, splicing it into the
cached per-chunk manifests — the model itself (opaque: gradient descent
over all rows) still retrains on the assembled whole. Compare the delta
day's wall time and per-node chunk counters against day 0.

    PYTHONPATH=src:benchmarks python examples/incremental_census.py
"""
import dataclasses
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import workflows as W                          # noqa: E402
from repro.core import IterativeSession        # noqa: E402
from repro.core.config import EngineConfig     # noqa: E402
from repro.core.omp import Policy              # noqa: E402


def show(title, rep, seconds):
    ex = rep.execution
    print(f"\n=== {title} ===")
    print(f"  wall {seconds:.2f}s | computed {ex.n_computed}, "
          f"loaded {ex.n_loaded}")
    for n in sorted(set(ex.chunk_computed) | set(ex.chunk_reused)):
        print(f"   {n:12s} chunks: {ex.chunk_computed.get(n, 0)} computed, "
              f"{ex.chunk_reused.get(n, 0)} spliced from cache")
    print(f"  eval: {rep.outputs['dailyEval']}")


def main():
    knobs = dataclasses.replace(W.IncrementalCensusKnobs(),
                                n_chunks=6, rows_per_chunk=2_000)
    with tempfile.TemporaryDirectory() as workdir:
        sess = IterativeSession(workdir,
                                engine=EngineConfig(policy=Policy.ALWAYS))

        # Day 0: cold — every chunk of every chunked node computes.
        t0 = time.perf_counter()
        rep = sess.run(W.build_census_incremental(knobs))
        show("day 0 (cold: all chunks computed)", rep,
             time.perf_counter() - t0)

        # Day 1: one batch appended. The chunked nodes compute exactly
        # one new chunk each and splice the rest; only the opaque model
        # + eval recompute whole.
        knobs = dataclasses.replace(knobs, n_chunks=knobs.n_chunks + 1)
        t0 = time.perf_counter()
        rep = sess.run(W.build_census_incremental(knobs))
        show("day 1 (append: delta chunks spliced)", rep,
             time.perf_counter() - t0)


if __name__ == "__main__":
    main()
