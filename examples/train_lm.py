"""End-to-end driver: train a ~100M-parameter LM with segment checkpointing.

    # real ~100M model (slow on CPU; the real target is a TPU pod):
    PYTHONPATH=src python examples/train_lm.py --steps 300

    # CPU-sized demo of the same code path (~15M params):
    PYTHONPATH=src python examples/train_lm.py --small --steps 200

Interrupt it and re-run with --resume: training continues from the last
materialized segment on the exact same data stream.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CPU-sized model instead of the full ~100M")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume", action="store_true")
    args, rest = ap.parse_known_args()

    argv = ["--arch", "helix100m", "--steps", str(args.steps),
            "--workdir", "results/train_lm", "--segment-steps", "25",
            "--batch", "8", "--seq", "128", "--lr", "3e-3"]
    if args.small:
        argv += ["--reduced", "--batch", "16"]
    if args.resume:
        argv += ["--resume"]
    sys.argv = ["train"] + argv + rest
    train_mod.main()


if __name__ == "__main__":
    main()
