"""Quickstart: the paper's census workflow (Fig. 3), three iterations.

Shows the full Helix loop: declare a workflow in the DSL → run → edit →
re-run with cross-iteration reuse. Watch the per-node states: iteration 2
(a PPR edit) loads/prunes everything upstream of the changed reducer.

    PYTHONPATH=src:benchmarks python examples/quickstart.py
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import workflows as W                     # noqa: E402
from repro.core import IterativeSession   # noqa: E402


def show(title, rep):
    print(f"\n=== {title} ===")
    print(f"  total {rep.total_seconds:.2f}s | "
          f"computed {rep.execution.n_computed}, "
          f"loaded {rep.execution.n_loaded}, "
          f"pruned {rep.execution.n_pruned} | "
          f"store {rep.store_bytes / 1e6:.1f} MB")
    for n, s in sorted(rep.execution.states.items()):
        mark = "*" if n in rep.original else " "
        print(f"   {mark} {n:14s} {s.value}")
    print(f"  output: {rep.outputs['checkResults']}")


def main():
    knobs = dataclasses.replace(W.CensusKnobs(), n_rows=30_000)
    with tempfile.TemporaryDirectory() as workdir:
        sess = IterativeSession(workdir)

        # Iteration 0: everything is original → computed.
        rep = sess.run(W.build_census(knobs))
        show("iteration 0 (initial)", rep)

        # Iteration 1: PPR edit — switch the metric to F1. Only the reducer
        # re-runs; DPR and the trained model are reused.
        knobs = dataclasses.replace(knobs, eval_metric="f1")
        rep = sess.run(W.build_census(knobs))
        show("iteration 1 (PPR edit: metric → f1)", rep)

        # Iteration 2: L/I edit — change regularization. The model retrains
        # but the parsed rows / features load from the store.
        knobs = dataclasses.replace(knobs, reg=0.01)
        rep = sess.run(W.build_census(knobs))
        show("iteration 2 (L/I edit: reg → 0.01)", rep)


if __name__ == "__main__":
    main()
