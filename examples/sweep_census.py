"""Shared-store concurrent sweep: a census hyperparameter grid.

Eight variants — regularization × decision threshold — run concurrently
against ONE materialization store. The max-flow planner plus the store's
in-flight dedupe (per-signature compute leases) turn every shared prefix
into a single compute and N-1 loads:

* all 8 arms share the data pipeline (parse, feature extraction, example
  assembly) — computed once fleet-wide;
* each pair of arms with the same ``reg`` also shares the trained model;
* only the per-arm evaluation differs.

Compare the sweep wall-clock against running the same arms isolated
(fresh store each — no reuse possible), and note ``fleet_computes``:
no signature is computed twice.

    PYTHONPATH=src:benchmarks python examples/sweep_census.py
"""
import dataclasses
import os
import sys
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import workflows as W                                # noqa: E402
from repro.core import IterativeSession, grid, run_sweep   # noqa: E402


def main():
    base = dataclasses.replace(W.CensusKnobs(), n_rows=30_000)
    axes = {"reg": [0.01, 0.03, 0.1, 0.3],
            "eval_threshold": [0.5, 0.7]}
    variants = grid(base, axes, W.build_census, name="census")
    print(f"sweeping {len(variants)} variants: "
          + ", ".join(v.name for v in variants))

    # --- isolated baseline: every arm cold, its own store, same
    # concurrency as the sweep (so the difference below is pure reuse) ----
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as root:
        with ThreadPoolExecutor(max_workers=len(variants)) as pool:
            list(pool.map(
                lambda iv: IterativeSession(
                    os.path.join(root, f"iso{iv[0]}")).run(iv[1].build()),
                enumerate(variants)))
    iso_s = time.perf_counter() - t0

    # --- one shared store, all arms concurrent ----------------------------
    with tempfile.TemporaryDirectory() as workdir:
        report = run_sweep(workdir, variants)
        report.raise_errors()

        print(f"\nisolated (no reuse): {iso_s:6.2f}s")
        print(f"shared-store sweep:  {report.wall_seconds:6.2f}s   "
              f"→ {iso_s / report.wall_seconds:.2f}x")
        print(f"store size: {report.store_bytes / 1e6:.1f} MB")

        recomputed = {s: c for s, c in report.fleet_computes().items()
                      if c > 1}
        print(f"signatures computed more than once fleet-wide: "
              f"{len(recomputed)}")

        print("\nper-arm results:")
        for r in report.results:
            ex = r.report.execution
            out = r.report.outputs["checkResults"]
            computed = ex.n_computed - len(ex.deduped)
            reused = ex.n_loaded + len(ex.deduped)
            print(f"  {r.variant.name:40s} "
                  f"computed {computed:2d}  reused {reused:2d}  "
                  f"{out['metric']}={out['value']:.3f}")


if __name__ == "__main__":
    main()
