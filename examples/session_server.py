"""Session server: several "users" iterating concurrently on one host.

Starts a :class:`SessionServer` on a scratch workdir, exposes it on a unix
socket, and drives it from three concurrent clients — two iterating on
the census workflow (they share the data pipeline and, when their ``reg``
matches, the trained model), one on an independent toy workflow. The
server's global scheduler orders submissions shared-prefix-first and the
dispatch log shows who ran when; the signature-multiplicity map is what
fed OMP's amortized materialization threshold.

    PYTHONPATH=src:benchmarks python examples/session_server.py
"""
import os
import shutil
import sys
import tempfile
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

import numpy as np                                     # noqa: E402

from repro.core import Workflow                        # noqa: E402
from repro.serve import SessionServer, connect_unix    # noqa: E402
import workflows as W                                  # noqa: E402


def build_census(reg: float = 0.1, eval_threshold: float = 0.5):
    knobs = W.CensusKnobs(n_rows=20_000, reg=reg,
                          eval_threshold=eval_threshold)
    return W.build_census(knobs)


def build_toy(scale: float = 1.0):
    wf = Workflow("toy")
    src = wf.source("grid", lambda: np.linspace(0, 1, 200_000),
                    config="v1")
    trapezoid = getattr(np, "trapezoid", None) or np.trapz
    out = wf.reducer("area", lambda x, s=scale: {
        "area": float(trapezoid(np.sin(x * np.pi) * s, x))},
        [src], config=("s", scale))
    wf.output(out)
    return wf


def main() -> None:
    workdir = os.path.join(tempfile.gettempdir(), "helix-serve-demo")
    shutil.rmtree(workdir, ignore_errors=True)
    server = SessionServer(
        workdir,
        registry={"census": build_census, "toy": build_toy},
        n_sessions=2, pool_workers=4)
    sock = server.serve_unix(os.path.join(workdir, "helix.sock"))
    print(f"server on {sock} (schedule={server.scheduler.mode})")

    results = {}

    def user(name: str, workflow: str, params: dict) -> None:
        client = connect_unix(sock)
        job = client.submit(workflow, params, name=name)
        results[name] = client.wait(job)
        client.close()

    users = [
        ("alice", "census", {"reg": 0.1, "eval_threshold": 0.5}),
        ("bob", "census", {"reg": 0.1, "eval_threshold": 0.7}),
        ("carol", "toy", {"scale": 2.0}),
    ]
    threads = [threading.Thread(target=user, args=u) for u in users]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    server.shutdown()

    print(f"dispatch order: {server.dispatch_log}")
    for name, summary in sorted(results.items()):
        ex = summary["execution"]
        print(f"{name:6s} {summary['status']:5s} "
              f"run={summary['run_seconds']:.2f}s "
              f"computed={ex['n_computed']} loaded={ex['n_loaded']} "
              f"deduped={ex['n_deduped']} -> {summary['outputs']}")


if __name__ == "__main__":
    main()
