#!/usr/bin/env python
"""Markdown link checker for the docs CI job (no external deps).

Checks every ``[text](target)`` markdown link in the given files:

* relative file links must exist on disk (anchors are stripped; ``#foo``
  anchors within the same file are checked against its headings).
  Resolution follows markdown semantics — relative to the *linking
  file's* directory — but intra-repo links written repo-root-relative
  (the common GitHub style, e.g. ``docs/architecture.md`` linked from
  another file under ``docs/``) are also accepted when they resolve
  from the repo root (``--root``, default: the current directory), as
  are ``/``-absolute targets (resolved against the repo root, which is
  how GitHub renders them);
* ``http(s)`` URLs are format-checked only (CI must not flake on the
  network);
* code spans and fenced code blocks are ignored.

Exit code 1 lists every broken link with file:line.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
URL_RE = re.compile(r"^[a-z][a-z0-9+.-]*://\S+$")


def heading_anchor(text: str) -> str:
    """GitHub-style anchor for a heading line."""
    text = re.sub(r"[`*_]", "", text.strip().lower())
    text = re.sub(r"[^\w\s-]", "", text)
    return re.sub(r"[\s]+", "-", text).strip("-")


def strip_code(lines: list[str]) -> list[str]:
    """Blank out fenced code blocks and inline code spans."""
    out, fenced = [], False
    for line in lines:
        if line.lstrip().startswith("```"):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else re.sub(r"`[^`]*`", "", line))
    return out


def check_file(path: str, root: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        raw = f.read().splitlines()
    lines = strip_code(raw)
    anchors = {heading_anchor(m.group(1))
               for line in raw for m in [HEADING_RE.match(line)] if m}
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    for i, line in enumerate(lines, 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if URL_RE.match(target):
                continue  # external: format already validated by the regex
            if target.startswith("mailto:"):
                continue
            if target.startswith("#"):
                if heading_anchor(target[1:]) not in anchors \
                        and target[1:] not in anchors:
                    errors.append(f"{path}:{i}: missing anchor {target}")
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if rel.startswith("/"):
                # GitHub renders /-absolute targets against the repo
                # root, not the filesystem root.
                if not os.path.exists(os.path.join(root,
                                                   rel.lstrip("/"))):
                    errors.append(f"{path}:{i}: missing file {target}")
                continue
            if os.path.exists(os.path.join(base, rel)):
                continue   # proper markdown resolution (file-relative)
            # Fallback: intra-repo links written root-relative (a file
            # under docs/ saying ``docs/operations.md``). Previously
            # only links *from* the repo root resolved these — the same
            # link inside docs/ was a false "missing file".
            if os.path.exists(os.path.join(root, rel)):
                continue
            errors.append(f"{path}:{i}: missing file {target}")
    return errors


def main(argv: list[str]) -> int:
    root = os.getcwd()
    files = []
    it = iter(argv)
    for arg in it:
        if arg == "--root":
            root = next(it, root)
        else:
            files.append(arg)
    files = files or ["README.md"]
    all_errors: list[str] = []
    for path in files:
        if not os.path.exists(path):
            all_errors.append(f"{path}: file not found")
            continue
        all_errors.extend(check_file(path, root))
    for e in all_errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not all_errors else f'{len(all_errors)} broken'}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
