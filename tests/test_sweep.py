"""Sweep engine: cross-variant reuse over one shared store.

Correctness bar (ISSUE 2 acceptance): a K-variant sweep sharing one store
produces outputs bit-identical to K isolated cold runs, and computes each
shared-prefix signature exactly once fleet-wide.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (IterativeSession, Policy, SweepVariant, grid,
                        run_sweep)
from repro.core.locking import HAVE_FLOCK, StorageLedger
from repro.core.workflow import Workflow

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


class Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1


@dataclasses.dataclass(frozen=True)
class ToyKnobs:
    reg: float = 0.1
    bias: float = 0.0


def build_toy(k: ToyKnobs, calls: Calls | None = None) -> Workflow:
    """source → parse → feat (slow, shared) → model(reg) → eval(bias):
    everything up to ``feat`` is knob-independent, i.e. the shared
    prefix; the learner/eval tail differs per variant."""
    def count(name):
        if calls is not None:
            calls.hit(name)

    wf = Workflow("toy")
    src = wf.source(
        "src", lambda: (count("src"), np.arange(4096, dtype=np.float64))[1],
        config="v1")
    parsed = wf.scanner(
        "parse", lambda x: (count("parse"), x.reshape(64, 64))[1],
        [src], config="v1")

    def featurize(m):
        count("feat")
        acc = m.copy()
        # Heavy enough (~100ms) that a 32 KB store LOAD decisively beats
        # recomputing even when a loaded machine makes the measured store
        # bandwidth look terrible — the OEP planner must pick LOAD for
        # late arrivals by economics, not by luck.
        for _ in range(2000):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [parsed], config="v1")
    model = wf.learner(
        "model",
        lambda z, reg=k.reg: (count("model"), float(np.sum(z * z)) * reg)[1],
        [feat], config=("LR", k.reg))
    out = wf.reducer(
        "eval",
        lambda m, b=k.bias: (count("eval"), {"score": m + b})[1],
        [model], config=("eval", k.bias))
    wf.output(out)
    return wf


REGS = [0.1, 0.2, 0.4]


def _variants(calls=None):
    return [SweepVariant(name=f"reg{r}",
                         build=(lambda r=r: build_toy(ToyKnobs(reg=r),
                                                      calls)),
                         knobs=ToyKnobs(reg=r))
            for r in REGS]


def test_sweep_bit_identical_to_isolated_cold_runs(tmp_path):
    sweep = run_sweep(str(tmp_path / "shared"), _variants())
    sweep.raise_errors()
    isolated = {}
    for r in REGS:
        sess = IterativeSession(str(tmp_path / f"iso{r}"))
        isolated[f"reg{r}"] = sess.run(build_toy(ToyKnobs(reg=r))).outputs
    assert sweep.outputs == isolated   # bit-identical, not approx


def test_sweep_computes_shared_prefix_exactly_once(tmp_path):
    calls = Calls()
    sweep = run_sweep(str(tmp_path), _variants(calls))
    sweep.raise_errors()
    # Shared-prefix operators ran once fleet-wide; per-variant tails ran K×.
    assert calls.counts["feat"] == 1
    assert calls.counts["src"] == 1
    assert calls.counts["parse"] == 1
    assert calls.counts["model"] == len(REGS)
    assert calls.counts["eval"] == len(REGS)
    # and the report agrees: no signature was computed by two variants
    assert all(n == 1 for n in sweep.fleet_computes().values())
    assert sweep.wasted_recomputes() == 0


def test_sweep_shared_budget_respected(tmp_path):
    budget = 40_000  # fits ~one 64×64 float64 feat value, not much more
    sweep = run_sweep(str(tmp_path), _variants(),
                      storage_budget_bytes=budget)
    sweep.raise_errors()
    assert all(r.report is not None for r in sweep.results)
    # the shared on-disk ledger never exceeded the budget
    from repro.core import Store
    store = Store(str(tmp_path / "store"))
    assert store.total_bytes() <= budget
    assert 0 <= StorageLedger(store.ledger_path).used() <= budget


def test_sweep_sequential_arrival_reuses_store(tmp_path):
    """n_concurrent=1: later variants arrive after the prefix landed and
    the OEP planner turns it into plain LOADs — reuse without any lease
    contention."""
    calls = Calls()
    sweep = run_sweep(str(tmp_path), _variants(calls), n_concurrent=1)
    sweep.raise_errors()
    assert calls.counts["feat"] == 1
    later = [r.report for r in sweep.results[1:]]
    assert all(rep.execution.n_loaded >= 1 for rep in later)


def test_sweep_shared_nondet_nonces(tmp_path):
    """share_nondet pins one nonce per node name sweep-wide: the unseeded
    featurizer runs once and every variant sees the same draw."""
    calls = Calls()

    def build_nd(scale):
        wf = Workflow("nd")
        src = wf.source("src", lambda: np.ones(512), config="v1")

        def noisy(x):
            calls.hit("noisy")
            return x * np.random.default_rng().uniform(0.5, 1.5, x.shape)

        feat = wf.extractor("noisy", noisy, [src], config="n1",
                            deterministic=False)
        out = wf.reducer("out",
                         lambda z, s=scale: {"v": float(z.sum()) * s},
                         [feat], config=("s", scale))
        wf.output(out)
        return wf

    scales = [1.0, 2.0, 4.0]
    variants = [SweepVariant(name=f"s{s}", build=(lambda s=s: build_nd(s)))
                for s in scales]
    sweep = run_sweep(str(tmp_path / "pinned"), variants)
    sweep.raise_errors()
    assert calls.counts["noisy"] == 1
    vals = [sweep.outputs[f"s{s}"]["out"]["v"] / s for s in scales]
    assert vals[0] == vals[1] == vals[2]   # same underlying draw

    # independent mode: every variant draws (and computes) its own
    calls2 = Calls()

    def build_nd2(scale):
        wf = Workflow("nd")
        src = wf.source("src", lambda: np.ones(512), config="v1")

        def noisy(x):
            calls2.hit("noisy")
            return x * np.random.default_rng().uniform(0.5, 1.5, x.shape)

        feat = wf.extractor("noisy", noisy, [src], config="n1",
                            deterministic=False)
        out = wf.reducer("out",
                         lambda z, s=scale: {"v": float(z.sum()) * s},
                         [feat], config=("s", scale))
        wf.output(out)
        return wf

    variants2 = [SweepVariant(name=f"s{s}", build=(lambda s=s: build_nd2(s)))
                 for s in scales]
    sweep2 = run_sweep(str(tmp_path / "indep"), variants2,
                       share_nondet=False)
    sweep2.raise_errors()
    assert calls2.counts["noisy"] == len(scales)


def test_grid_helper():
    vs = grid(ToyKnobs(), {"reg": [0.1, 0.2], "bias": [0.0, 1.0]},
              build=lambda k: build_toy(k))
    assert len(vs) == 4
    assert {v.knobs.reg for v in vs} == {0.1, 0.2}
    assert {v.knobs.bias for v in vs} == {0.0, 1.0}
    wf = vs[0].build()
    assert "feat" in wf.build().nodes


def test_sweep_policies_and_reuse_second_wave(tmp_path):
    """A second sweep over the same workdir (e.g. a refined grid) reuses
    the first wave's materializations through ordinary OEP planning."""
    calls = Calls()
    run_sweep(str(tmp_path), _variants(calls)).raise_errors()
    assert calls.counts["feat"] == 1
    second = [SweepVariant(name="reg9",
                           build=(lambda: build_toy(ToyKnobs(reg=0.9),
                                                    calls)))]
    sweep2 = run_sweep(str(tmp_path), second, policy=Policy.OPT)
    sweep2.raise_errors()
    assert calls.counts["feat"] == 1   # loaded, not recomputed
    rep = sweep2.results[0].report
    assert rep.execution.n_loaded >= 1
