"""Change tracking (paper §4.2): recursive equivalence via signatures."""
from repro.core.dag import DAG, Node
from repro.core.signature import compute_signatures, source_version


def lin(n=3, versions=None, det=None):
    versions = versions or ["v"] * n
    det = det or [True] * n
    return DAG([Node(f"n{i}", None, (f"n{i-1}",) if i else (),
                     version=versions[i], deterministic=det[i])
                for i in range(n)])


def test_identical_dags_equivalent():
    assert compute_signatures(lin()) == compute_signatures(lin())


def test_change_propagates_to_descendants_only():
    s0 = compute_signatures(lin(versions=["v", "v", "v"]))
    s1 = compute_signatures(lin(versions=["v", "w", "v"]))
    assert s0["n0"] == s1["n0"]          # ancestor unaffected
    assert s0["n1"] != s1["n1"]          # edited node deprecated
    assert s0["n2"] != s1["n2"]          # descendant deprecated (Def. 2b)


def test_nondeterministic_never_equivalent():
    d = lin(det=[True, False, True])
    a = compute_signatures(d)
    b = compute_signatures(d)
    assert a["n0"] == b["n0"]
    assert a["n1"] != b["n1"] and a["n2"] != b["n2"]
    # pinned nonces restore reproducibility (test hook)
    a = compute_signatures(d, nonces={"n1": "x"})
    b = compute_signatures(d, nonces={"n1": "x"})
    assert a == b


def test_source_version_hashes_config():
    assert source_version({"reg": 0.1}) != source_version({"reg": 0.2})
    assert source_version({"reg": 0.1}) == source_version({"reg": 0.1})
