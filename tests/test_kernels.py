"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
from repro.kernels.ssd import ops as ssd_ops, ref as ssd_ref
from repro.models.ssd import ssd_scan_reference

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash attn
FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, causal, window, qoff
    (2, 128, 128, 4, 2, 64, True, 0, 0),
    (1, 256, 256, 8, 8, 32, True, 0, 0),
    (2, 128, 128, 4, 4, 64, True, 16, 0),
    (1, 64, 128, 4, 2, 64, True, 0, 64),
    (2, 128, 128, 2, 1, 128, False, 0, 0),
    (1, 512, 512, 2, 2, 64, True, 128, 0),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, H, KV, D, causal, window, qoff = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    out = fa_ops.flash_attention(q, k, v, jnp.int32(qoff),
                                 causal=causal, window=window)
    exp = fa_ref.attention_ref(q, k, v, qoff, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


def test_flash_fallback_on_odd_shapes():
    q = jax.random.normal(KEY, (1, 15, 2, 64))
    k = jax.random.normal(KEY, (1, 15, 2, 64))
    out = fa_ops.flash_attention(q, k, k, causal=True, window=0)
    exp = fa_ref.attention_ref(q, k, k, 0, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


# -------------------------------------------------------------------- SSD
SSD_CASES = [
    # b, S, H, P, N, chunk
    (2, 64, 3, 16, 32, 16),
    (1, 128, 4, 32, 16, 32),
    (2, 48, 2, 16, 8, 16),      # S not a chunk multiple (padding path)
    (1, 96, 8, 8, 8, 32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_sequential_oracle(case):
    b, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, N)) * 0.5
    y1, h1 = ssd_ops.ssd(x, dt, a, B, C, chunk=chunk)
    y2, h2 = ssd_ref.ssd_ref(x, dt, a, B, C)
    scale = float(jnp.max(jnp.abs(y2))) + 1e-6
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4 * max(float(jnp.max(jnp.abs(h2))), 1))


def test_ssd_xla_chunked_matches_oracle():
    b, S, H, P, N, chunk = 2, 64, 3, 16, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, N)) * 0.5
    y1, h1 = ssd_scan_reference(x, dt, a, B, C, chunk)
    y2, h2 = ssd_ref.ssd_ref(x, dt, a, B, C)
    scale = float(jnp.max(jnp.abs(y2))) + 1e-6
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2),
                               atol=1e-4 * scale)


def test_ssd_respects_initial_state():
    b, S, H, P, N, chunk = 1, 32, 2, 8, 8, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, N)) * 0.5
    h0 = jax.random.normal(ks[5], (b, H, P, N))
    y1, _ = ssd_ops.ssd(x, dt, a, B, C, chunk=chunk, h0=h0)
    y2, _ = ssd_ref.ssd_ref(x, dt, a, B, C, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape", [(8, 128), (3, 5, 64), (257, 96), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(KEY, shape[-1:], jnp.float32)
    out = rn_ops.rmsnorm(x, w)
    exp = rn_ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=2e-2)
