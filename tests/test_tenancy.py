"""Tenant-isolation harness (ISSUE 10).

Proves the multi-tenancy contract end to end:

* **Param schemas / allowlists** — the schema is an allowlist; unknown
  params, wrong types, out-of-range values, and off-allowlist workflows
  are rejected at submission, before the factory runs.
* **Quota ledger** — ``TenantQuota`` is transactional (flock'd JSON);
  ``ScopedLedger`` reserves two-phase (tenant quota first, fleet budget
  second, rollback on fleet refusal), credits foreign evictions to the
  fleet only, and reports ``scope_exhausted`` so the Materializer never
  evicts a neighbor chasing quota room.
* **Fair share** (hypothesis, ``--hypothesis-profile=ci-deep`` in CI) —
  random tenant weights × random job streams: no backlogged tenant
  starves, served compute-seconds stay within the classic weighted-fair
  bound, and the pick inside each tenant's turn is exactly what the
  prefix-first scheduler would choose.
* **Concurrency stress** — K tenants × M socket clients against a
  2-shard :class:`~repro.serve.router.FleetRouter`: results bit-identical
  to isolated runs, per-shard ledger == on-disk bytes, zero evictions of
  live entries, and a quota-exhausted tenant gets a clean
  ``quota_exceeded`` wire error (not a hang, not a silent evict).
* **Counter races** — the store's tier hit/miss counters are exact under
  concurrent loads (regression for the unlocked ``+=`` they replaced).
"""
import os
import threading

import numpy as np
import pytest

from repro.core import IterativeSession
from repro.core.config import EngineConfig, StoreConfig
from repro.core.locking import HAVE_FLOCK, StorageLedger
from repro.core.store import Store
from repro.core.workflow import Workflow
from repro.serve import (FleetRouter, InProcessClient, QuotaExceeded,
                         ScopedLedger, ServerError, SessionServer,
                         TenantQuota, TenantScheduler, TenantSpec,
                         connect_unix, validate_params)
from repro.serve.scheduler import PrefixScheduler
from repro.serve.tenancy import resolve_tenant

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


class Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self.counts.get(name, 0)


def build_family(family: str, reg: float, calls: Calls | None = None,
                 work: int = 600) -> Workflow:
    """src → feat (slow, shared within a family) → model(reg) → eval."""
    def count(name):
        if calls is not None:
            calls.hit(name)

    wf = Workflow(f"{family}-{reg}")
    src = wf.source(
        "src",
        lambda: np.arange(4096, dtype=np.float64).reshape(64, 64),
        config=("v1", family))

    def featurize(m):
        count(f"feat_{family}")
        acc = m.copy()
        for _ in range(work):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config=("feat", family))
    model = wf.learner(
        "model", lambda z, r=reg: float(np.sum(z * z)) * r,
        [feat], config=("LR", reg))
    out = wf.reducer("eval", lambda m: {"score": m}, [model],
                     config=("eval",))
    wf.output(out)
    return wf


# ---------------------------------------------------------------------------
# identity + param schemas (admission-time gates)
# ---------------------------------------------------------------------------
def test_resolve_tenant_catch_all_and_unknown():
    table = {"acme": TenantSpec(weight=3.0)}
    assert resolve_tenant(table, "acme").weight == 3.0
    with pytest.raises(PermissionError, match="unknown tenant"):
        resolve_tenant(table, "ghost")
    table["*"] = TenantSpec(weight=0.5)
    assert resolve_tenant(table, "ghost").weight == 0.5


def test_validate_params_is_an_allowlist():
    schema = {"reg": {"type": "float", "min": 0.0, "max": 1.0},
              "family": "str", "deep": "bool",
              "mode": ["grid", "random"]}
    validate_params("fam", {"reg": 0.3, "family": "a", "deep": True,
                            "mode": "grid"}, schema)
    # unknown param: the schema IS the allowlist
    with pytest.raises(ValueError, match="not in schema"):
        validate_params("fam", {"exploit": 1}, schema)
    # type errors — bool is not an acceptable float/int
    with pytest.raises(ValueError, match="must be float"):
        validate_params("fam", {"reg": "0.3"}, schema)
    with pytest.raises(ValueError, match="must be float"):
        validate_params("fam", {"reg": True}, schema)
    # range + choice constraints
    with pytest.raises(ValueError, match="above max"):
        validate_params("fam", {"reg": 2.0}, schema)
    with pytest.raises(ValueError, match="below min"):
        validate_params("fam", {"reg": -0.1}, schema)
    with pytest.raises(ValueError, match="must be one of"):
        validate_params("fam", {"mode": "exhaustive"}, schema)
    # a bad schema is an error too, not a silent pass
    with pytest.raises(ValueError, match="unknown schema type"):
        validate_params("fam", {"reg": 1}, {"reg": "quaternion"})


# ---------------------------------------------------------------------------
# the quota ledger + the tenant-scoped view the Materializer sees
# ---------------------------------------------------------------------------
def test_tenant_quota_reserve_adjust_charge(tmp_path):
    q = TenantQuota(str(tmp_path / "tenants.json"))
    assert q.try_reserve_bytes("a", 600.0, quota=1000.0)
    assert not q.try_reserve_bytes("a", 600.0, quota=1000.0)  # would bust
    assert q.bytes_used("a") == 600.0                         # no side effect
    assert q.try_reserve_bytes("b", 600.0, quota=1000.0)      # independent
    q.adjust_bytes("a", -700.0)                               # clamped at 0
    assert q.bytes_used("a") == 0.0
    q.charge_compute("a", 1.5)
    q.charge_compute("a", 2.5)
    assert q.compute_used("a") == pytest.approx(4.0)
    q.check_compute("a", TenantSpec(compute_seconds=5.0))     # under: fine
    with pytest.raises(QuotaExceeded) as exc:
        q.check_compute("a", TenantSpec(compute_seconds=4.0))
    assert exc.value.resource == "compute_seconds"
    assert exc.value.tenant == "a"
    # an instantly exhausted tenant: zero quota trips on first check
    with pytest.raises(QuotaExceeded):
        q.check_compute("fresh", TenantSpec(compute_seconds=0.0))


def test_scoped_ledger_two_phase_and_foreign_credit(tmp_path):
    fleet = StorageLedger(str(tmp_path / "ledger.json"))
    fleet.ensure(0.0)
    quota = TenantQuota(str(tmp_path / "tenants.json"))
    led = ScopedLedger(fleet, quota, "a", quota_bytes=1000.0)

    # tenant-side refusal: no fleet reservation happens at all
    assert not led.try_reserve(2000.0, budget=1e9)
    assert fleet.used() == 0.0 and quota.bytes_used("a") == 0.0
    assert led.scope_exhausted(2000.0) and not led.scope_exhausted(500.0)

    # fleet-side refusal rolls the tenant phase back
    assert not led.try_reserve(500.0, budget=100.0)
    assert fleet.used() == 0.0 and quota.bytes_used("a") == 0.0

    # a clean reservation lands on both ledgers; release undoes both
    assert led.try_reserve(500.0, budget=1e9)
    assert fleet.used() == 500.0 and quota.bytes_used("a") == 500.0
    led.adjust(100.0)
    assert fleet.used() == 600.0 and quota.bytes_used("a") == 600.0
    led.release(600.0)
    assert fleet.used() == 0.0 and quota.bytes_used("a") == 0.0

    # foreign evictions credit the fleet only — not this tenant's meter
    assert led.try_reserve(300.0, budget=1e9)
    led.credit_foreign(100.0)
    assert fleet.used() == 200.0 and quota.bytes_used("a") == 300.0

    # an uncapped scope never reports exhaustion
    free = ScopedLedger(fleet, quota, "b")
    assert not free.scope_exhausted(1e18)


# ---------------------------------------------------------------------------
# fair share: property-based, against the scheduler itself
# ---------------------------------------------------------------------------
class _SimStore:
    """Minimal store surface for the scheduler: nothing materialized."""

    def has(self, sig):
        return False


class _SimCost:
    """Unit compute-cost model."""

    def compute_cost(self, sig):
        return 1.0


class _SimJob:
    """The scheduler-facing job shape (see ``_SchedJob``)."""

    def __init__(self, jid, seq, tenant, sigs, dur):
        self.id = jid
        self.seq = seq
        self.tenant = tenant
        self.sigs = frozenset(sigs)
        self.priority = 0
        self.dur = dur


def _drive_fair(weights: list[float], durs: list[list[float]]):
    """Serve every tenant's stream to exhaustion, one slot, checking the
    fair-queueing invariants at every dispatch. Returns served seconds
    per tenant over the all-backlogged interval."""
    tenants = [f"t{i}" for i in range(len(weights))]
    wmap = dict(zip(tenants, weights))
    sched = TenantScheduler(PrefixScheduler(_SimStore(), _SimCost(),
                                            "prefix"), wmap)
    queued, jid = [], 0
    for ti, t in enumerate(tenants):
        for j, d in enumerate(durs[ti]):
            # half of each tenant's jobs share an intra-tenant prefix so
            # the inner prefix-first order has something to prefer
            sigs = {f"{t}:prefix"} if j % 2 == 0 else {f"{t}:solo{j}"}
            sigs.add(f"{t}:tail{j}")
            job = _SimJob(jid, jid, t, sigs, d)
            queued.append(job)
            sched.add(job)
            jid += 1

    d_max = max(max(ds) for ds in durs)
    min_w = min(weights)
    served = {t: 0.0 for t in tenants}
    dispatches = []
    while queued and all(any(j.tenant == t for j in queued)
                         for t in tenants):
        backlogged = {j.tenant for j in queued}
        expect = min(backlogged,
                     key=lambda t: (sched.virtual_time(t), t))
        picked = sched.pick(queued, inflight=set())
        # the fair pass chose the lowest-virtual-time tenant...
        assert picked.tenant == expect
        # ...and within that tenant's queue, exactly the prefix-first
        # choice — fairness composes with reuse, it does not replace it
        mine = [j for j in queued if j.tenant == picked.tenant]
        assert picked is sched.inner.pick(mine, set())
        sched.note_dispatch(picked)
        sched.note_finish(picked, picked.dur)
        served[picked.tenant] += picked.dur
        queued.remove(picked)
        sched.remove(picked)
        dispatches.append(picked.tenant)
        # WFQ bound: while all tenants are backlogged, virtual times
        # stay within d_max/min_w of each other (one max-size job at
        # the minimum weight is the worst possible overshoot)
        vts = [sched.virtual_time(t) for t in tenants]
        assert max(vts) - min(vts) <= d_max / min_w + 1e-9
    return served, dispatches, sched


if HAVE_HYPOTHESIS:
    @settings(deadline=None)
    @given(st.data())
    def test_fair_share_bounds_hypothesis(data):
        """Random weights × random job streams: no starvation, bounded
        virtual-time spread, prefix-first within each tenant's turn."""
        n = data.draw(st.integers(2, 4), label="n_tenants")
        weights = [data.draw(st.floats(0.5, 8.0), label=f"w{i}")
                   for i in range(n)]
        durs = [[data.draw(st.floats(0.05, 1.5), label=f"d{i}_{j}")
                 for j in range(10)] for i in range(n)]
        served, dispatches, sched = _drive_fair(weights, durs)
        # no starvation: with everyone backlogged from t=0, the first n
        # dispatches go to n distinct tenants (each starts at virtual
        # time 0 and is charged before the next pick)
        assert len(set(dispatches[:n])) == n
        assert all(s > 0.0 for s in served.values())
        # the status() snapshot agrees with the meters we tracked
        snap = sched.snapshot()
        for t in sorted(served):
            assert snap[t]["served_s"] == pytest.approx(served[t])
            assert snap[t]["weight"] == pytest.approx(
                max(weights[int(t[1:])], 1e-9))


def test_fair_share_weighted_ratio_converges():
    """Deterministic long stream: a 3:1 weight split serves ~3:1 compute
    seconds over the backlogged interval (within the discretization
    error of one job)."""
    weights = [3.0, 1.0]
    durs = [[0.1] * 400, [0.1] * 400]
    served, _, _ = _drive_fair(weights, durs)
    # t1 exhausts its backlog bound first; compare over the interval
    ratio = served["t0"] / max(served["t1"], 1e-9)
    assert 2.0 <= ratio <= 4.0


def test_fair_share_is_work_conserving():
    """A tenant with no backlog donates its share: the other tenant is
    served at every dispatch instead of the slot idling."""
    sched = TenantScheduler(
        PrefixScheduler(_SimStore(), _SimCost(), "prefix"),
        {"a": 1.0, "b": 100.0})
    jobs = [_SimJob(i, i, "a", {f"a:{i}"}, 0.1) for i in range(5)]
    for j in jobs:
        sched.add(j)
    queued = list(jobs)
    while queued:
        picked = sched.pick(queued, set())       # b has nothing queued
        assert picked.tenant == "a"
        sched.note_dispatch(picked)
        sched.note_finish(picked, picked.dur)
        queued.remove(picked)
        sched.remove(picked)


# ---------------------------------------------------------------------------
# admission gates on the server (in-process = same _handle path as wire)
# ---------------------------------------------------------------------------
def test_quota_exhausted_is_a_clean_refusal(tmp_path):
    """An exhausted tenant's submit raises QuotaExceeded at admission —
    it never queues, never hangs, and neighbors are unaffected."""
    tenants = {"payg": TenantSpec(compute_seconds=0.0),
               "flat": TenantSpec()}
    server = SessionServer(
        str(tmp_path), registry={"fam": build_family},
        tenants=tenants, engine=EngineConfig(schedule="fair"),
        poll_interval=0.01)
    try:
        broke = InProcessClient(server, tenant="payg")
        with pytest.raises(QuotaExceeded) as exc:
            broke.submit("fam", {"family": "a", "reg": 0.1})
        assert exc.value.tenant == "payg"
        assert exc.value.resource == "compute_seconds"
        # the neighbor is untouched by payg's refusal
        ok = InProcessClient(server, tenant="flat")
        job = ok.submit("fam", {"family": "a", "reg": 0.1})
        assert ok.wait(job)["status"] == "done"
        # ...and flat's served seconds are now on the quota meter
        assert server.quota.compute_used("flat") > 0.0
        # unknown tenants are refused outright (no "*" catch-all here)
        ghost = InProcessClient(server, tenant="ghost")
        with pytest.raises(ServerError, match="unknown tenant"):
            ghost.submit("fam", {"family": "a", "reg": 0.1})
    finally:
        server.shutdown()


def test_workflow_allowlist_and_schema_on_the_server(tmp_path):
    """Per-tenant workflow allowlists and per-workflow param schemas
    gate submit_named before the factory ever runs."""
    fired = Calls()

    def fam(family, reg):
        fired.hit("factory")
        return build_family(family, reg)

    server = SessionServer(
        str(tmp_path), registry={"fam": fam, "other": fam},
        tenants={"narrow": TenantSpec(workflows=("other",)),
                 "*": TenantSpec()},
        param_schemas={"fam": {"family": "str",
                               "reg": {"type": "float",
                                       "min": 0.0, "max": 1.0}}},
        poll_interval=0.01)
    try:
        narrow = InProcessClient(server, tenant="narrow")
        with pytest.raises(QuotaExceeded) as exc:
            narrow.submit("fam", {"family": "a", "reg": 0.1})
        assert exc.value.resource == "workflow"
        anyone = InProcessClient(server, tenant="anyone")
        with pytest.raises(ServerError, match="not in schema"):
            anyone.submit("fam", {"family": "a", "reg": 0.1,
                                  "backdoor": 1})
        with pytest.raises(ServerError, match="above max"):
            anyone.submit("fam", {"family": "a", "reg": 5.0})
        assert fired.get("factory") == 0      # nothing reached a factory
        job = anyone.submit("fam", {"family": "a", "reg": 0.5})
        assert anyone.wait(job)["status"] == "done"
        assert fired.get("factory") == 1
    finally:
        server.shutdown()


def test_storage_quota_refuses_without_evicting(tmp_path):
    """A storage-capped tenant degrades to not-materializing: its jobs
    still finish (bit-identical), nothing is evicted on its behalf, and
    an uncapped neighbor's entries stay on disk."""
    calls = Calls()
    server = SessionServer(
        str(tmp_path / "srv"),
        registry={"fam": lambda family, reg:
                  build_family(family, reg, calls)},
        tenants={"capped": TenantSpec(storage_bytes=1.0),
                 "free": TenantSpec()},
        storage=StoreConfig(budget_bytes=50e6),
        poll_interval=0.01)
    try:
        free = InProcessClient(server, tenant="free")
        jf = free.submit("fam", {"family": "f", "reg": 0.2})
        assert free.wait(jf)["status"] == "done"
        n_entries = len(server.store.entries())
        assert n_entries > 0                  # the free tenant persisted

        capped = InProcessClient(server, tenant="capped")
        jc = capped.submit("fam", {"family": "c", "reg": 0.2})
        out = capped.wait(jc)
        assert out["status"] == "done"        # graceful, not an error
        iso = IterativeSession(str(tmp_path / "iso"))
        assert out["outputs"] == iso.run(build_family("c", 0.2)).outputs
        # the 1-byte quota admitted nothing new and evicted nothing
        assert len(server.store.entries()) == n_entries
        assert server.eviction_log == []
        assert server.quota.bytes_used("capped") == 0.0
        # fleet ledger still reconciles with the bytes on disk
        assert StorageLedger(server.store.ledger_path).used() == \
            pytest.approx(server.store.total_bytes())
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# the stress harness: K tenants × M socket clients × a 2-shard router
# ---------------------------------------------------------------------------
def test_multitenant_router_stress(tmp_path):
    """K tenants × M concurrent socket clients against a 2-shard fleet:
    every tenant's results are bit-identical to an isolated run, each
    shard's ledger matches its on-disk bytes, no eviction ever removed
    an entry a live submission wanted, and the quota-exhausted tenant
    got a clean wire error while everyone else kept working."""
    tenant_regs = {"acme": (0.1, 0.3), "bravo": (0.2, 0.4),
                   "cairo": (0.15, 0.35)}
    tenants = {t: TenantSpec(weight=w) for t, w in
               (("acme", 3.0), ("bravo", 1.0), ("cairo", 1.0))}
    tenants["payg"] = TenantSpec(compute_seconds=0.0)
    schemas = {"fam": {"family": "str",
                       "reg": {"type": "float", "min": 0.0, "max": 1.0}}}
    calls = Calls()
    registry = {"fam": lambda family, reg:
                build_family(family, reg, calls)}

    servers, shard_paths = {}, {}
    for sid in ("s0", "s1"):
        srv = SessionServer(
            str(tmp_path / sid), registry=registry, tenants=tenants,
            param_schemas=schemas,
            engine=EngineConfig(schedule="fair", n_sessions=2),
            poll_interval=0.01)
        shard_paths[sid] = srv.serve_unix(str(tmp_path / f"{sid}.sock"))
        servers[sid] = srv

    results: dict[tuple, dict] = {}
    quota_errors: list[QuotaExceeded] = []
    failures: list[BaseException] = []
    lock = threading.Lock()

    def worker(tenant, my_regs):
        # routers are not thread-safe: one per client thread, over the
        # same shard table — deterministic hashing makes them agree
        router = FleetRouter(shard_paths, registry=registry,
                             tenant=tenant, timeout=60.0)
        try:
            jobs = [(r, router.submit("fam", {"family": tenant,
                                              "reg": r}))
                    for r in my_regs]
            for r, job in jobs:
                out = router.wait(job, timeout=120.0)
                assert out["status"] == "done", out
                with lock:
                    results[(tenant, r)] = out["outputs"]
        finally:
            router.close()

    def broke_worker():
        router = FleetRouter(shard_paths, registry=registry,
                             tenant="payg", timeout=60.0)
        try:
            router.submit("fam", {"family": "payg", "reg": 0.1})
        except QuotaExceeded as e:
            with lock:
                quota_errors.append(e)
        finally:
            router.close()

    def run(fn, *args):
        def wrapped():
            try:
                fn(*args)
            except BaseException as e:   # noqa: BLE001 - collected
                with lock:
                    failures.append(e)
        t = threading.Thread(target=wrapped, daemon=True)
        t.start()
        return t

    try:
        threads = [run(broke_worker)]
        for tenant, regs in tenant_regs.items():
            # M=2 socket clients per tenant, splitting its arms
            threads.append(run(worker, tenant, regs[:1]))
            threads.append(run(worker, tenant, regs[1:]))
        for t in threads:
            t.join(timeout=180.0)
            assert not t.is_alive(), "stress worker hung"
        assert not failures, failures

        # the quota-exhausted tenant got exactly one clean refusal
        assert len(quota_errors) == 1
        assert quota_errors[0].tenant == "payg"
        assert quota_errors[0].resource == "compute_seconds"

        # bit-identity: every tenant's wire outputs == an isolated run
        for (tenant, reg), outputs in sorted(results.items()):
            iso = IterativeSession(str(tmp_path / f"iso-{tenant}-{reg}"))
            assert outputs == iso.run(build_family(tenant, reg)).outputs
        assert len(results) == sum(len(r) for r in tenant_regs.values())

        for sid, srv in servers.items():
            # per-shard ledger == bytes actually on disk
            assert StorageLedger(srv.store.ledger_path).used() == \
                pytest.approx(srv.store.total_bytes()), sid
            # zero evictions of entries a live submission wanted
            assert all(not e["live"] for e in srv.eviction_log), sid
            # the status() wire surface carries the same proof
            client = InProcessClient(srv, tenant="acme")
            snap = client.status()
            assert snap["tenants"]["n_evictions_live"] == 0
            assert "payg" not in {
                t for t, u in snap["tenants"]["usage"].items()
                if u.get("compute_s", 0.0) > 0.0}

        # prefix affinity: each family was computed on exactly one
        # shard, exactly once fleet-wide (both clients of a tenant — and
        # both router instances — agreed on placement)
        for tenant in tenant_regs:
            assert calls.get(f"feat_{tenant}") == 1, tenant
    finally:
        for srv in servers.values():
            srv.shutdown()


# ---------------------------------------------------------------------------
# tier counters are exact under concurrency (regression: unlocked +=)
# ---------------------------------------------------------------------------
def test_tier_counters_exact_under_concurrent_loads(tmp_path):
    """T threads × N loads of a memory-resident entry: the hit counter
    equals T·N exactly. Lost updates from the old unlocked ``+=`` made
    the stress harness's accounting assertions flaky."""
    store = Store(str(tmp_path / "store"), mem_budget_bytes=64e6)
    store.save("aa11", "x", np.arange(4096, dtype=np.float64))
    store.writer_drain()
    assert store.mem_has("aa11")
    T, N = 8, 200
    start = threading.Barrier(T)

    def hammer():
        start.wait()
        for _ in range(N):
            store.load("aa11")

    threads = [threading.Thread(target=hammer) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
        assert not t.is_alive()
    with store._stats_lock:
        hits = store.load_stats["memory"]["hits"]
        misses = store.load_stats["memory"]["misses"]
    assert hits == T * N
    assert misses == 0
    snap = store.tier_status()
    assert snap["local"]["misses"] == 0
