"""Elastic restart: a value materialized under mesh A restores under mesh B.

Runs in a subprocess with 8 forced host devices (the test suite itself must
keep seeing 1 device), saving a train-state-like pytree sharded over an
(8,)-mesh and reloading it onto a (4,2) mesh with different specs.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.store import Store

    store = Store(sys.argv[1])
    mesh_a = jax.make_mesh((8,), ("data",))
    value = {
        "w": jax.device_put(jnp.arange(64 * 16, dtype=jnp.float32
                                       ).reshape(64, 16),
                            NamedSharding(mesh_a, P("data", None))),
        "m": jax.device_put(jnp.ones((32, 8), jnp.bfloat16),
                            NamedSharding(mesh_a, P("data", None))),
        "step": 7,
    }
    store.save("sig-elastic", "state", value)

    # --- "restart" on a different mesh with different sharding -----------
    mesh_b = jax.make_mesh((4, 2), ("data", "model"),
                           devices=jax.devices()[:8])
    shard_b = NamedSharding(mesh_b, P("model", "data"))
    loaded, _ = store.load(
        "sig-elastic",
        sharding_for_leaf=lambda i, shape, dt: shard_b
        if shape == (64, 16) else None)
    w = loaded["w"]
    assert isinstance(w, jax.Array) and w.sharding == shard_b, w.sharding
    np.testing.assert_array_equal(np.asarray(w),
                                  np.arange(64 * 16).reshape(64, 16))
    np.testing.assert_array_equal(np.asarray(loaded["m"], np.float32),
                                  np.ones((32, 8)))
    assert loaded["step"] == 7
    print("ELASTIC_OK")
""")


def test_elastic_reshard_roundtrip(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path / "store")],
        capture_output=True, text=True, timeout=300,
        cwd=os.path.join(os.path.dirname(__file__), os.pardir))
    assert "ELASTIC_OK" in proc.stdout, proc.stdout + proc.stderr
