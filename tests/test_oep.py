"""OPT-EXEC-PLAN: exactness (Theorem 2), constraints, paper's Fig. 4 shape."""
import random

import pytest

pytest.importorskip(
    "hypothesis", reason="dev dependency; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import brute_force_plan, plan, plan_runtime
from repro.core.dag import DAG, Node, State, validate_states
from repro.core.pruning import slice_from_outputs


def random_sliced_dag(rng: random.Random, n: int):
    nodes = []
    for i in range(n):
        parents = tuple(f"n{j}" for j in range(i) if rng.random() < 0.4)
        nodes.append(Node(name=f"n{i}", fn=None, parents=parents,
                          is_output=(i == n - 1 or rng.random() < 0.2)))
    full = DAG(nodes)
    keep = slice_from_outputs(full)
    return full.subgraph(keep)


def random_instance(seed: int):
    rng = random.Random(seed)
    dag = random_sliced_dag(rng, rng.randint(1, 7))
    names = dag.topological()
    cc = {m: rng.randint(1, 20) * 1.0 for m in names}
    lc = {m: (rng.randint(1, 20) * 1.0 if rng.random() < 0.6 else None)
          for m in names}
    orig = {m for m in names if rng.random() < 0.25}
    # originality propagates down (recursive signatures)
    changed = True
    while changed:
        changed = False
        for name in names:
            nd = dag.nodes[name]
            if name not in orig and any(p in orig for p in nd.parents):
                orig.add(name)
                changed = True
    for o in orig:
        lc[o] = None
    return dag, cc, lc, orig


@settings(max_examples=150, deadline=None)
@given(st.integers(0, 10_000))
def test_maxflow_matches_bruteforce(seed):
    dag, cc, lc, orig = random_instance(seed)
    s1 = plan(dag, cc, lc, orig)
    t1 = plan_runtime(dag, s1, cc, lc)
    _, t2 = brute_force_plan(dag, cc, lc, orig)
    assert abs(t1 - t2) < 1e-6


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 10_000))
def test_plan_satisfies_constraints(seed):
    dag, cc, lc, orig = random_instance(seed)
    states = plan(dag, cc, lc, orig)
    validate_states(dag, states)       # Constraint 2 + outputs non-pruned
    for n in orig:                     # Constraint 1 (strict, sliced DAG)
        assert states[n] is State.COMPUTE


def test_fig4_example():
    """The paper's Fig. 4 intuition: loading a node prunes its ancestors;
    computing a node forces parents live."""
    nodes = [
        Node("a", None, ()), Node("b", None, ("a",)),
        Node("c", None, ("b",)), Node("out", None, ("c",), is_output=True),
    ]
    dag = DAG(nodes)
    cc = {"a": 10.0, "b": 10.0, "c": 10.0, "out": 1.0}
    # c materialized & cheap to load → a, b pruned
    lc = {"a": None, "b": None, "c": 1.0, "out": None}
    states = plan(dag, cc, lc, original={"out"})
    assert states == {"a": State.PRUNE, "b": State.PRUNE,
                      "c": State.LOAD, "out": State.COMPUTE}
    # loading c is expensive → recompute chain
    lc["c"] = 100.0
    states = plan(dag, cc, lc, original={"out"})
    assert states["c"] is State.COMPUTE
    assert states["a"] is State.COMPUTE and states["b"] is State.COMPUTE


def test_everything_pruned_when_output_loadable():
    nodes = [Node("x", None, ()), Node("y", None, ("x",), is_output=True)]
    dag = DAG(nodes)
    states = plan(dag, {"x": 5.0, "y": 5.0}, {"x": 1.0, "y": 0.1}, set())
    assert states == {"x": State.PRUNE, "y": State.LOAD}


def test_cycle_detection():
    with pytest.raises(ValueError):
        DAG([Node("a", None, ("b",)), Node("b", None, ("a",))])
