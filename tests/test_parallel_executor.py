"""Pipelined ready-set executor: determinism vs the sequential engine,
prefetch bounding, writer-queue accounting, and store thread-safety."""
import os
import threading

import numpy as np
import pytest

from repro.core.dag import DAG, Node, State
from repro.core.executor import execute
from repro.core.omp import Materializer, Policy
from repro.core.store import Store

N = 1000


def _sig(name: str) -> str:
    return f"sig{abs(hash(name)) % 10**8:08d}{name}"


def diamond_dag(width: int = 12) -> DAG:
    """src → width branches (f_i → g_i) → join → out, plus dangling d_i
    extractors (prune fodder)."""
    nodes = [Node("src", lambda: np.arange(N, dtype=np.float64))]
    gs = []
    for i in range(width):
        nodes.append(Node(f"f{i}", (lambda i=i: lambda x: x + i)(),
                          parents=("src",)))
        nodes.append(Node(f"g{i}", lambda x: x * 2.0, parents=(f"f{i}",)))
        gs.append(f"g{i}")
    for i in range(3):
        nodes.append(Node(f"d{i}", lambda x: x - 1.0, parents=("src",)))
    nodes.append(Node("join", lambda *vs: np.sum(vs, axis=0),
                      parents=tuple(gs)))
    nodes.append(Node("out", lambda v: float(v.sum()), parents=("join",),
                      is_output=True))
    return DAG(nodes)


def diamond_states(dag: DAG, load_branches=(3, 7)) -> dict[str, State]:
    """Mixed plan: two branches load their g_i (f_i pruned), the dangling
    d_i are pruned, everything else computes."""
    states = {name: State.COMPUTE for name in dag.nodes}
    for i in load_branches:
        states[f"g{i}"] = State.LOAD
        states[f"f{i}"] = State.PRUNE
    for i in range(3):
        states[f"d{i}"] = State.PRUNE
    return states


def seed_loads(store: Store, load_branches=(3, 7)) -> None:
    x = np.arange(N, dtype=np.float64)
    for i in load_branches:
        store.save(_sig(f"g{i}"), f"g{i}", (x + i) * 2.0)


def run_engine(tmp_path, tag: str, max_workers: int, budget: float,
               async_mat: bool = False, prefetch_depth: int = 4):
    dag = diamond_dag()
    states = diamond_states(dag)
    store = Store(str(tmp_path / f"store-{tag}"))
    seed_loads(store)
    sigs = {n: _sig(n) for n in dag.nodes}
    mat = Materializer(policy=Policy.ALWAYS, storage_budget_bytes=budget)
    report = execute(dag, sigs, states, store, mat,
                     async_materialization=async_mat,
                     max_workers=max_workers,
                     prefetch_depth=prefetch_depth)
    if async_mat:
        store.writer_drain()
    return report, store


def test_parallel_matches_sequential_with_budget_hit(tmp_path):
    """Wide diamond, mixed COMPUTE/LOAD/PRUNE, storage budget exhausted
    mid-run: 1 and 8 workers must produce identical outputs, runtimes
    coverage, materialization decisions (incl. reasons), and store
    contents."""
    budget = 6.5 * N * 8  # fits ~6 of the ~13 candidate values
    rep1, store1 = run_engine(tmp_path, "w1", 1, budget)
    rep8, store8 = run_engine(tmp_path, "w8", 8, budget)

    assert rep1.outputs.keys() == rep8.outputs.keys()
    assert rep1.outputs["out"] == rep8.outputs["out"]
    assert set(rep1.runtime) == set(rep8.runtime)
    assert rep1.states == rep8.states
    # Decision determinism: same nodes materialized/skipped for the same
    # reasons, despite arbitrary completion order under 8 workers.
    assert rep1.materialized == rep8.materialized
    assert rep1.skipped_mat == rep8.skipped_mat
    assert set(store1.entries()) == set(store8.entries())
    # The budget genuinely ran out mid-run.
    assert any("budget exhausted" in r for r in rep8.skipped_mat.values())
    assert rep8.materialized


def test_parallel_matches_ground_truth(tmp_path):
    x = np.arange(N, dtype=np.float64)
    expected = float(np.sum([(x + i) * 2.0 for i in range(12)]))
    rep, _ = run_engine(tmp_path, "gt", 8, float("inf"))
    assert rep.outputs["out"] == expected
    assert rep.max_workers == 8


def test_prune_load_accounting(tmp_path):
    rep, _ = run_engine(tmp_path, "acct", 4, float("inf"))
    assert rep.n_loaded == 2
    assert rep.n_pruned == 5   # f3, f7, d0..d2
    assert rep.n_computed == len(rep.states) - 7


def test_mat_seconds_accounted_in_async_mode(tmp_path):
    """satellite: mat_seconds must not silently read 0 under the writer
    queue — it aggregates measured write wall time in both modes."""
    rep_sync, _ = run_engine(tmp_path, "sync", 1, float("inf"),
                             async_mat=False)
    rep_async, store = run_engine(tmp_path, "async", 4, float("inf"),
                                  async_mat=True)
    assert rep_sync.mat_seconds > 0
    assert rep_async.mat_seconds > 0
    assert rep_sync.materialized == rep_async.materialized
    # everything decided for materialization actually hit the disk
    for name in rep_async.materialized:
        assert store.has(_sig(name))


def test_prefetch_depth_bounds_resident_loads(tmp_path):
    """Loads feeding a chain of consumers must not all be prefetched at
    once: residency stays within prefetch_depth (+1 for the starvation
    guard admitting a needed load)."""
    k = 8
    nodes = [Node(f"L{i}", None) for i in range(k)]
    prev = None
    for i in range(k):
        parents = (f"L{i}",) if prev is None else (prev, f"L{i}")
        fn = ((lambda v: v + 0.0) if prev is None
              else (lambda acc, v: acc + v))
        nodes.append(Node(f"C{i}", fn, parents=parents,
                          is_output=(i == k - 1)))
        prev = f"C{i}"
    dag = DAG(nodes)
    states = {f"L{i}": State.LOAD for i in range(k)}
    states.update({f"C{i}": State.COMPUTE for i in range(k)})
    store = Store(str(tmp_path / "store"))
    sigs = {n: _sig(n) for n in dag.nodes}
    for i in range(k):
        store.save(sigs[f"L{i}"], f"L{i}", np.full(N, float(i)))
    rep = execute(dag, sigs, states, store,
                  Materializer(policy=Policy.NEVER),
                  max_workers=4, prefetch_depth=2)
    assert rep.outputs[f"C{k-1}"] == pytest.approx(
        sum(range(k)) * np.ones(N))
    assert rep.peak_resident_loads <= 3
    # and with a generous depth everything may be prefetched
    rep2 = execute(dag, sigs, states, store,
                   Materializer(policy=Policy.NEVER),
                   max_workers=4, prefetch_depth=k)
    assert rep2.peak_resident_loads <= k


def test_worker_exception_propagates(tmp_path):
    dag = DAG([Node("a", lambda: 1.0),
               Node("b", lambda x: 1.0 / 0.0, parents=("a",),
                    is_output=True)])
    states = {"a": State.COMPUTE, "b": State.COMPUTE}
    store = Store(str(tmp_path / "store"))
    with pytest.raises(ZeroDivisionError):
        execute(dag, {n: _sig(n) for n in dag.nodes}, states, store,
                Materializer(policy=Policy.NEVER), max_workers=4)


def test_oos_order_matches_sequential_semantics():
    dag = diamond_dag(width=3)
    states = {name: State.COMPUTE for name in dag.nodes}
    for i in range(3):
        states[f"d{i}"] = State.PRUNE
    order = dag.oos_order(states)
    # src goes out of scope once every f_i (its last compute children) ran;
    # out (terminal, no children) goes out of scope right after itself.
    assert order.index("src") < order.index("join")
    assert order[-1] == "out"
    assert all(states[n] is not State.PRUNE for n in order)
    assert len(order) == len([n for n in dag.nodes
                              if states[n] is not State.PRUNE])


# ---------------------------------------------------------------------------
# Store concurrency
# ---------------------------------------------------------------------------
def test_store_concurrent_save_load_delete_same_prefix(tmp_path):
    """Hammer save/load/delete on signatures sharing one directory prefix:
    readers must never observe a torn entry, and the store must stay
    consistent."""
    store = Store(str(tmp_path))
    sigs = [f"ab{i:02d}" for i in range(4)]   # all under root/ab/
    for s in sigs:
        store.save(s, f"node-{s}", np.full(256, 0.0))
    errors: list[BaseException] = []
    stop = threading.Event()

    def saver(sig, gen0):
        g = gen0
        while not stop.is_set():
            store.save(sig, f"node-{sig}", np.full(256, float(g)))
            g += 1

    def loader(sig):
        while not stop.is_set():
            try:
                value, _ = store.load(sig)
            except FileNotFoundError:
                continue  # concurrently deleted — acceptable
            # atomic publish: the array must be one whole generation
            assert value.shape == (256,)
            assert np.all(value == value[0]), "torn read"

    def deleter(sig):
        while not stop.is_set():
            store.delete(sig)
            store.save(sig, f"node-{sig}", np.full(256, -1.0))

    def wrap(fn, *args):
        def run():
            try:
                fn(*args)
            except BaseException as e:  # pragma: no cover - failure path
                errors.append(e)
                stop.set()
        return run

    threads = [threading.Thread(target=wrap(saver, sigs[0], 1)),
               threading.Thread(target=wrap(saver, sigs[0], 1000)),
               threading.Thread(target=wrap(loader, sigs[0])),
               threading.Thread(target=wrap(loader, sigs[1])),
               threading.Thread(target=wrap(saver, sigs[1], 1)),
               threading.Thread(target=wrap(deleter, sigs[2])),
               threading.Thread(target=wrap(saver, sigs[3], 7))]
    for t in threads:
        t.start()
    stopper = threading.Timer(2.0, stop.set)
    stopper.start()
    for t in threads:
        t.join(timeout=30)
    stopper.cancel()
    stop.set()
    assert not errors, errors
    # post-race: every surviving sig loads cleanly
    for s in sigs:
        if store.has(s):
            value, _ = store.load(s)
            assert value.shape == (256,)
    assert store.total_bytes() >= 0


def test_stale_tmp_dirs_reaped_and_not_counted(tmp_path):
    import subprocess

    store = Store(str(tmp_path))
    store.save("ee55", "x", np.zeros(16))
    # simulate a crash mid-save: an orphaned staging dir (owned by a
    # provably dead pid) holding a meta.json
    proc = subprocess.Popen(["true"])
    proc.wait()
    stale = tmp_path / "ee" / f"ee56.tmp-{proc.pid}-456-0"
    stale.mkdir(parents=True)
    (stale / "meta.json").write_text('{"name": "ghost", "nbytes": 999}')
    assert set(store.entries()) == {"ee55"}   # never counted as an entry
    assert set(Store(str(tmp_path), heal=True).entries()) == {"ee55"}
    assert not stale.exists()                 # reaped on healing reopen

    # a staging dir owned by a *live* process must never be reaped
    live = tmp_path / "ee" / f"ee57.tmp-{os.getpid()}-456-0"
    live.mkdir(parents=True)
    Store(str(tmp_path), heal=True)
    assert live.exists()


def test_writer_queue_bounded_and_ordered(tmp_path):
    store = Store(str(tmp_path), max_inflight_bytes=4 * 256 * 8)
    pendings = [store.save_enqueue(f"cd{i:02d}", f"n{i}",
                                   np.full(256, float(i)))
                for i in range(16)]
    infos = [p.result(timeout=30) for p in pendings]
    assert all(i.nbytes == 256 * 8 for i in infos)
    store.writer_drain()
    for i in range(16):
        value, _ = store.load(f"cd{i:02d}")
        assert np.all(value == float(i))
