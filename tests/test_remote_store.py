"""Remote store tier: cross-host materialization sharing (ISSUE 5).

Correctness bar:

* a local miss served by the remote tier is bit-identical to a local hit
  (write-through → read-through round-trip);
* TTL lease expiry releases a crashed holder's compute lease (heartbeat
  stops → a sibling host acquires), while a heartbeating holder keeps it;
* two "hosts" (separate workdirs, one object store) compute each shared
  signature exactly once fleet-wide;
* eviction — remote-tier or local — never deletes an entry another host
  holds a live remote lease/pin on;
* a failing backend degrades the tier to local-only instead of failing
  the session.

The "hosts" are separate Store/workdir instances inside one process —
faithful, because nothing they share crosses process memory except the
ObjectStore handle, which is itself just files (FsObjectStore).
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import IterativeSession
from repro.core.locking import HAVE_FLOCK
from repro.core.remote import (FsObjectStore, ObjectStore, RemoteStore,
                               as_remote_store)
from repro.core.store import Store
from repro.core.workflow import Workflow

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


def _bucket(tmp_path) -> FsObjectStore:
    return FsObjectStore(str(tmp_path / "bucket"))


def _value(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((64, 32)),
            "idx": np.arange(128, dtype=np.int32),
            "meta": {"k": seed}}


# -- object-store backend ----------------------------------------------------

def test_fs_object_store_roundtrip_and_conditional_put(tmp_path):
    fs = _bucket(tmp_path)
    assert fs.get("a/b") is None
    fs.put("a/b", b"v1")
    assert fs.get("a/b") == b"v1"
    fs.put("a/b", b"v2")                       # replace
    assert fs.get("a/b") == b"v2"
    assert fs.put_if_absent("a/b", b"v3") is False   # taken
    assert fs.get("a/b") == b"v2"              # loser changed nothing
    assert fs.put_if_absent("a/c", b"w") is True
    assert sorted(fs.list("a/")) == ["a/b", "a/c"]
    assert fs.delete("a/b") is True
    assert fs.delete("a/b") is False
    assert fs.exists("a/c") and not fs.exists("a/b")


# -- write-through / read-through --------------------------------------------

def test_local_miss_remote_hit_bit_identical(tmp_path):
    """Host A saves; host B (fresh workdir) loads through the remote
    tier bit-identically, and the fetch populates B's local tier."""
    fs = _bucket(tmp_path)
    value = _value(7)
    store_a = Store(str(tmp_path / "hostA"), remote=RemoteStore(fs))
    store_a.save("ab12", "node", value,
                 extra_meta={"compute_s": 2.0, "load_s_est": 0.01})
    store_a.writer_drain()          # drains the upload queue too
    assert store_a.remote.exists("ab12")

    store_b = Store(str(tmp_path / "hostB"), remote=RemoteStore(fs))
    assert store_b.has("ab12") and not store_b.has_local("ab12")
    # meta falls back to the remote commit marker (planner load costs)
    assert store_b.meta("ab12")["nbytes"] > 0
    got, secs = store_b.load("ab12")
    assert got["w"].dtype == value["w"].dtype
    np.testing.assert_array_equal(got["w"], value["w"])
    np.testing.assert_array_equal(got["idx"], value["idx"])
    assert got["meta"] == {"k": 7}
    assert secs > 0
    # read-through populated the local tier; the next load is local
    assert store_b.has_local("ab12")
    assert store_b.remote_hits == 1
    store_b.load("ab12")
    assert store_b.remote_hits == 1


def test_upload_is_idempotent_and_refused_over_budget(tmp_path):
    fs = _bucket(tmp_path)
    remote = RemoteStore(fs, budget_bytes=1)   # nothing fits
    store = Store(str(tmp_path / "host"), remote=remote)
    store.save("ab12", "node", np.ones(1024))
    store.writer_drain()
    assert not remote.exists("ab12")           # refused, local-only
    assert remote.stats.n_upload_refused >= 1
    assert store.has_local("ab12")             # the session still works


# -- TTL leases --------------------------------------------------------------

def test_ttl_lease_expiry_releases_crashed_holder(tmp_path):
    """heartbeat stops (crash) → expiry frees the lease for a sibling;
    a live heartbeat keeps it held past the TTL."""
    fs = _bucket(tmp_path)
    crashed = RemoteStore(fs, lease_ttl=0.3, heartbeats=False)
    sibling = RemoteStore(fs, lease_ttl=0.3)
    lease = crashed.acquire_compute("ab12")
    assert lease is not None
    assert sibling.acquire_compute("ab12") is None   # live holder
    time.sleep(0.45)                                 # TTL passes, no renewal
    taken = sibling.acquire_compute("ab12")
    assert taken is not None                         # crash-released
    # a heartbeating holder survives several TTLs
    assert crashed.acquire_compute("ab12") is None
    time.sleep(0.45)
    assert crashed.acquire_compute("ab12") is None   # renewed, still held
    taken.release()
    assert not sibling.lease_live("ab12")
    sibling.close()
    crashed.close()


def test_wait_compute_follows_remote_holder(tmp_path):
    """A waiter on another 'host' polls the remote lease; the holder's
    publish-before-release means the waiter finds the entry on wake."""
    fs = _bucket(tmp_path)
    store_a = Store(str(tmp_path / "hostA"),
                    remote=RemoteStore(fs, lease_ttl=5.0))
    store_b = Store(str(tmp_path / "hostB"),
                    remote=RemoteStore(fs, lease_ttl=5.0))
    lease = store_a.acquire_compute("ab12")
    assert lease is not None
    assert store_b.acquire_compute("ab12") is None   # cross-host exclusion

    def holder():
        time.sleep(0.25)
        store_a.save("ab12", "node", _value(1))
        store_a.upload_now("ab12")          # publish-before-release
        lease.release()

    t = threading.Thread(target=holder)
    t.start()
    assert store_b.wait_compute("ab12", timeout=30)
    assert store_b.has("ab12")
    got, _ = store_b.load("ab12")
    np.testing.assert_array_equal(got["w"], _value(1)["w"])
    t.join()


# -- two hosts, one workflow -------------------------------------------------

def _counting_workflow(tag: str, calls: dict, lock: threading.Lock):
    """src → feat (slow, shared) → out; every compute bumps a counter."""
    def count(name):
        with lock:
            calls[name] = calls.get(name, 0) + 1

    wf = Workflow("two-host")
    src = wf.source(
        "src", lambda: (count("src"),
                        np.arange(2048, dtype=np.float64))[1],
        config="v1")

    def featurize(x):
        count("feat")
        acc = x.reshape(32, 64).copy()
        for _ in range(600):    # heavy enough that LOAD decisively wins
            acc = np.tanh(acc @ acc.T @ acc / acc.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config="v1")
    out = wf.reducer(
        "out", lambda z, t=tag: {"score": float(np.sum(z)), "tag": t},
        [feat], config=("tail", tag))
    wf.output(out)
    return wf


def test_two_hosts_compute_each_shared_signature_once(tmp_path):
    """Two hosts (own workdirs, one object store) run workflows sharing
    a prefix concurrently: each shared signature is computed exactly
    once fleet-wide, and both get bit-identical prefix values. The
    shared-signature set is passed like real drivers (sweep pre-pass /
    server multiplicity map) pass it — that is what makes the lease
    holder force-persist even when it wins the race outright."""
    from repro.core import compute_signatures

    fs = _bucket(tmp_path)
    calls: dict = {}
    lock = threading.Lock()
    reports = {}
    barrier = threading.Barrier(2)
    sig_sets = [
        set(compute_signatures(
            _counting_workflow(f"h{i}", {}, lock).build()).values())
        for i in (0, 1)]
    shared = frozenset(sig_sets[0] & sig_sets[1])
    assert shared   # the prefix really is signature-equivalent

    def host(i):
        sess = IterativeSession(
            str(tmp_path / f"host{i}"), dedupe_inflight=True,
            store=Store(str(tmp_path / f"host{i}" / "store"),
                        remote=RemoteStore(fs, lease_ttl=30.0)))
        barrier.wait()
        reports[i] = sess.run(_counting_workflow(f"h{i}", calls, lock),
                              share_sigs=shared)
        sess.store.writer_drain()

    threads = [threading.Thread(target=host, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # shared prefix: exactly one compute fleet-wide
    assert calls["feat"] == 1, calls
    assert calls["src"] == 1, calls
    # per-host tails both ran, outputs agree on the shared part
    s0 = reports[0].outputs["out"]["score"]
    s1 = reports[1].outputs["out"]["score"]
    assert s0 == s1
    # the loser host loaded (planned LOAD or in-flight dedupe), never
    # recomputed
    n_feat_computed = sum(
        1 for r in reports.values()
        for n, s in r.execution.states.items()
        if n == "feat" and s.name == "COMPUTE"
        and n not in r.execution.deduped)
    assert n_feat_computed <= 1


# -- eviction vs leases ------------------------------------------------------

def test_remote_eviction_never_deletes_leased_entry(tmp_path):
    """Over-budget uploads evict lowest-benefit remote entries — but an
    entry another host pinned (or holds a compute lease on) is vetoed."""
    fs = _bucket(tmp_path)
    nb = np.ones(8192).nbytes
    remote = RemoteStore(fs, budget_bytes=int(nb * 2.5))
    store = Store(str(tmp_path / "hostA"), remote=remote)
    # two cheap entries fill the budget; "aa01" is the worst candidate
    store.save("aa01", "junk1", np.ones(8192))
    store.save("bb02", "junk2", np.ones(8192),
               extra_meta={"compute_s": 50.0, "load_s_est": 0.01})
    store.writer_drain()
    assert remote.exists("aa01") and remote.exists("bb02")

    # host B pins the *worst* candidate (it plans to LOAD it)
    host_b = RemoteStore(fs)
    pin = host_b.acquire_pin("aa01")
    assert pin is not None

    store.save("cc03", "hot", np.ones(8192),
               extra_meta={"compute_s": 99.0, "load_s_est": 0.01})
    store.writer_drain()
    # the pinned entry survived; the unpinned low-benefit one went
    assert remote.exists("aa01"), "evicted a remotely-pinned entry"
    assert remote.exists("cc03")
    assert not remote.exists("bb02")
    assert remote.stats.n_veto_protected >= 1
    assert remote.stats.n_evicted == 1

    pin.release()
    # unpinned now: the next over-budget upload may take it
    store.save("dd04", "hot2", np.ones(8192),
               extra_meta={"compute_s": 99.0, "load_s_est": 0.01})
    store.writer_drain()
    assert not remote.exists("aa01")
    host_b.close()


def test_read_pin_spans_tiers_for_remote_only_entries(tmp_path):
    """acquire_read on a remote-only entry takes a remote TTL pin, so no
    other host's eviction can delete it before the planned LOAD."""
    fs = _bucket(tmp_path)
    store_a = Store(str(tmp_path / "hostA"), remote=RemoteStore(fs))
    store_a.save("ab12", "node", np.ones(512))
    store_a.writer_drain()

    store_b = Store(str(tmp_path / "hostB"), remote=RemoteStore(fs))
    assert not store_b.has_local("ab12")
    pin = store_b.acquire_read("ab12")      # plan-time pin
    assert pin is not None
    assert store_b.remote.pinned("ab12")
    # another host's remote eviction respects the pin
    assert store_a.remote.delete_entry("ab12") == 0
    assert store_a.remote.stats.n_veto_protected >= 1
    pin.release()
    assert not store_b.remote.pinned("ab12")
    assert store_a.remote.delete_entry("ab12") > 0


# -- degradation -------------------------------------------------------------

class _FlakyBackend(ObjectStore):
    """Delegating backend that can be switched to hard-failing."""

    def __init__(self, inner: ObjectStore):
        self.inner = inner
        self.broken = False

    def _check(self):
        if self.broken:
            raise OSError("backend unreachable")

    def put(self, key, data):
        self._check()
        return self.inner.put(key, data)

    def get(self, key):
        self._check()
        return self.inner.get(key)

    def list(self, prefix):
        self._check()
        return self.inner.list(prefix)

    def delete(self, key):
        self._check()
        return self.inner.delete(key)

    def put_if_absent(self, key, data):
        self._check()
        return self.inner.put_if_absent(key, data)

    def exists(self, key):
        self._check()
        return self.inner.exists(key)


def test_remote_unreachable_degrades_to_local_only(tmp_path):
    """Backend failures mark the tier degraded for a cool-down; every
    store operation keeps working local-only (no exception escapes)."""
    flaky = _FlakyBackend(_bucket(tmp_path))
    remote = RemoteStore(flaky, degrade_seconds=3600.0)
    store = Store(str(tmp_path / "host"), remote=remote)
    store.save("ab12", "node", np.ones(64))
    store.writer_drain()
    assert remote.exists("ab12")

    flaky.broken = True
    remote.marker_meta("zz99", fresh=True)    # trips degradation
    assert not remote.available()
    assert remote.stats.n_errors >= 1
    # everything still works, local-tier only
    store.save("cd34", "node2", np.ones(64))
    store.writer_drain()
    assert store.has_local("cd34")
    assert store.has("cd34")
    assert not store.has("ef56")              # remote not consulted
    got, _ = store.load("ab12")               # was populated locally
    np.testing.assert_array_equal(got, np.ones(64))
    lease = store.acquire_compute("gh78")     # local-only lease works
    assert lease is not None
    lease.release()
    with pytest.raises(FileNotFoundError):
        store.load("ef56")                    # miss is a miss, not a hang


# -- observability -----------------------------------------------------------

def test_tier_status_and_lease_counts(tmp_path):
    """Store.tier_status reports per-tier bytes, entries, and a live
    lease census — the numbers SessionServer.status() surfaces."""
    fs = _bucket(tmp_path)
    store = Store(str(tmp_path / "host"), remote=RemoteStore(fs))
    store.save("ab12", "node", np.ones(256))
    store.writer_drain()
    lease = store.acquire_compute("cd34")
    pin = store.acquire_read("ab12")
    try:
        status = store.tier_status()
        local, remote = status["local"], status["remote"]
        assert local["entries"] == 1 and local["bytes"] > 0
        assert local["leases"]["compute"] == 1
        assert local["leases"]["pins"] == 1
        assert remote is not None and remote["available"]
        assert remote["entries"] == 1 and remote["bytes"] > 0
        assert remote["leases"]["compute"] == 1   # TTL lease object
        assert remote["n_uploads"] == 1
    finally:
        pin.release()
        lease.release()
    status = store.tier_status()
    assert status["local"]["leases"] == {"compute": 0, "pins": 0,
                                         "waiters": 0}


def test_server_status_reports_tiers(tmp_path):
    """SessionServer.status() carries the per-tier breakdown (the ISSUE
    5 observability bugfix: not just a single local byte count)."""
    from repro.serve.server import SessionServer

    server = SessionServer(str(tmp_path / "srv"),
                           remote=str(tmp_path / "bucket"))
    try:
        status = server.status()
        assert "tiers" in status
        assert status["tiers"]["local"]["leases"] == {
            "compute": 0, "pins": 0, "waiters": 0}
        assert status["tiers"]["remote"] is not None
        assert status["tiers"]["remote"]["available"]
        assert status["store_bytes"] == status["tiers"]["local"]["bytes"]
    finally:
        server.shutdown()


def test_as_remote_store_coercions(tmp_path):
    fs = _bucket(tmp_path)
    r = RemoteStore(fs)
    assert as_remote_store(None) is None
    assert as_remote_store(r) is r
    assert isinstance(as_remote_store(fs), RemoteStore)
    built = as_remote_store(str(tmp_path / "other"), budget_bytes=123.0)
    assert isinstance(built, RemoteStore)
    assert built.budget_bytes == 123.0
    with pytest.raises(TypeError):
        as_remote_store(42)


def test_multi_host_sweep_shares_via_remote_tier(tmp_path):
    """run_sweep(n_hosts=2, remote=...): separate per-host workdirs,
    shared remote tier — zero wasted recomputes and cross-host fetches."""
    from repro.core import SweepVariant, run_sweep

    calls: dict = {}
    lock = threading.Lock()
    variants = [
        SweepVariant(name=f"v{i}",
                     build=(lambda t=f"v{i}": _counting_workflow(
                         t, calls, lock)))
        for i in range(4)]
    report = run_sweep(str(tmp_path / "sweep"), variants, n_hosts=2,
                       remote=str(tmp_path / "bucket"))
    report.raise_errors()
    assert report.wasted_recomputes() == 0
    assert calls["feat"] == 1, calls          # once across both hosts
    assert report.remote.get("n_uploads", 0) >= 1
    assert report.remote.get("n_fetches", 0) >= 1
    # per-host workdirs actually exist (the deployment shape)
    assert os.path.isdir(str(tmp_path / "sweep" / "host0"))
    assert os.path.isdir(str(tmp_path / "sweep" / "host1"))
