"""Training substrate: loss goes down, grad-accum equivalence, optimizer."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import synth
from repro.data.pipeline import TokenBatcher
from repro.optim import adamw, compress
from repro.train import steps

KEY = jax.random.PRNGKey(0)


def tiny_cfg(**kw):
    cfg = configs.reduced(configs.get("internlm2-1.8b"))
    return dataclasses.replace(cfg, **kw)


def test_loss_decreases_on_learnable_stream():
    cfg = tiny_cfg()
    tokens = synth.lm_tokens(0, 60_000, cfg.vocab_size)
    batcher = TokenBatcher(tokens, batch=8, seq=32)
    state = steps.init_train_state(cfg, KEY)
    jstep = jax.jit(lambda st, b: steps.train_step(
        cfg, st, b, peak_lr=1e-2, warmup_steps=5, total_steps=100))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in batcher.batch_at(i).items()}
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accum_equivalent():
    """accum=2 must match accum=1 on the same global batch (up to fp)."""
    base = tiny_cfg(grad_accum=1)
    split = tiny_cfg(grad_accum=2)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, base.vocab_size)}
    s0 = steps.init_train_state(base, KEY)
    s1, _ = jax.jit(lambda st, b: steps.train_step(base, st, b))(s0, batch)
    s2, _ = jax.jit(lambda st, b: steps.train_step(split, st, b))(s0, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=1e-2)


def test_adamw_weight_decay_decoupled():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = adamw.init(params)
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    new, _ = adamw.update(params, grads, st, lr=0.1, weight_decay=0.5)
    # zero grad: matrices shrink by decay, vectors untouched
    assert float(new["w"][0, 0]) < 1.0
    assert float(new["b"][0]) == pytest.approx(1.0)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1.0
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_int8_error_feedback_roundtrip():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)),
                          jnp.float32)}
    ef = compress.ef_init(g)
    q, scale = compress.quantize_int8(g["w"])
    deq = compress.dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g["w"]))) < float(scale) + 1e-6
    # error feedback: residual carries the quantization error
    gf = g["w"] + ef.residual["w"]
    new_r = gf - deq
    np.testing.assert_allclose(np.asarray(new_r),
                               np.asarray(g["w"] - deq), atol=1e-6)


def test_deterministic_batcher():
    tokens = synth.lm_tokens(0, 10_000, 100)
    b1 = TokenBatcher(tokens, 4, 16, seed=3).batch_at(7)
    b2 = TokenBatcher(tokens, 4, 16, seed=3).batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.core.store import Store
    cfg = tiny_cfg()
    state = steps.init_train_state(cfg, KEY)
    mgr = CheckpointManager(Store(str(tmp_path)), "run1")
    mgr.save(10, state, async_=False)
    mgr.save(20, state, async_=False)
    assert mgr.latest_step() == 20
    restored = mgr.restore(20)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
