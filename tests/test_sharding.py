"""Sharding rules: divisibility fallback, EP vs expert-TP auto-selection,
batch/cache specs — resolved against an AbstractMesh (no 256 devices needed).
"""
import jax
from jax.sharding import PartitionSpec

from conftest import make_abstract_mesh
from repro import configs
from repro.models import registry
from repro.models.params import P, param_specs

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    return {jax.tree_util.keystr(p): v for p, v in flat}


def test_dense_2d_sharding():
    cfg = configs.get("yi-9b")
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), MESH))
    # embedding: vocab → model, embed → data
    emb = specs["['embed']"]
    assert tuple(emb) == ("model", "data")
    # attention wq (layers, embed, heads, hd): embed→data, heads→model
    wq = specs["['blocks']['attn']['wq']"]
    assert tuple(wq)[:3] == (None, "data", "model")


def test_kv_heads_fallback_replicated():
    cfg = configs.get("yi-9b")     # kv=4 < 16-way model axis
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), MESH))
    wk = specs["['blocks']['attn']['wk']"]
    # (layers, embed, kv_heads=4, hd): kv_heads cannot take 'model'
    assert tuple(wk) == (None, "data")


def test_granite_gets_expert_parallelism():
    cfg = configs.get("granite-moe-1b-a400m")   # 32 experts % 16 == 0
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), MESH))
    wg = specs["['blocks']['moe']['w_gate']"]
    # (layers, experts, embed, ff): experts→model (EP), embed→data
    assert tuple(wg) == (None, "model", "data")


def test_qwen2moe_falls_back_to_expert_tp():
    cfg = configs.get("qwen2-moe-a2.7b")        # 60 experts % 16 != 0
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), MESH))
    wg = specs["['blocks']['moe']['w_gate']"]
    # experts replicated; embed→data; expert ff 1408→model (expert-TP)
    assert tuple(wg) == (None, None, "data", "model")


def test_axis_used_once_per_tensor():
    p = P((32, 32), ("mlp", "heads"))           # both want 'model'
    spec = param_specs({"w": p}, MESH)["w"]
    entries = [e for e in tuple(spec) if e is not None]
    assert entries.count("model") <= 1


def test_multipod_mesh_resolution():
    cfg = configs.get("internlm2-1.8b")
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), MESH3))
    wq = specs["['blocks']['attn']['wq']"]
    assert "model" in tuple(wq)                 # still TP on the pod mesh


def test_single_device_mesh_all_replicated():
    mesh1 = make_abstract_mesh((1, 1), ("data", "model"))
    cfg = configs.reduced(configs.get("internlm2-1.8b"))
    specs = leaves_with_paths(param_specs(registry.param_defs(cfg), mesh1))
    assert all(all(e is None for e in tuple(s)) for s in specs.values())
