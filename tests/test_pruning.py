"""Pruning (§5.4) and the §6.6 stale purge's selection rules.

``stale_variants`` is the purge's brain, extracted so its suppression
rules are unit-testable without a store: never the node's own current
signature, and only names that are *original* this iteration (sibling
sweep variants and still-equivalent past runs are untouched). The
end-to-end tests pin the interaction the chunked materializations
introduce: purging a stale pre-append manifest must not cascade away the
prefix chunks the imminent delta splice reuses (``keep_chunks``).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import IterativeSession, compute_signatures
from repro.core.config import EngineConfig, StoreConfig
from repro.core.locking import StorageLedger
from repro.core.omp import Policy
from repro.core.pruning import (slice_from_outputs, stale_variants,
                                zero_weight_extractors)
from repro.core.workflow import Workflow


# -- slicing -----------------------------------------------------------------

def test_slice_drops_non_ancestors_of_outputs():
    wf = Workflow("slice")
    src = wf.source("src", lambda: np.arange(4.0), config="v1")
    used = wf.extractor("used", lambda x: x + 1, [src], config="v1")
    wf.extractor("raceExt", lambda x: x * 2, [src], config="v1")  # unused
    wf.output(used)
    keep = slice_from_outputs(wf.build())
    assert keep == {"src", "used"}


# -- data-driven pruning -----------------------------------------------------

def test_zero_weight_extractors_by_provenance():
    w = np.array([0.0, 0.5, 1e-12, 0.0])
    prov = {"a": [0, 2], "b": [1], "c": [3], "empty": []}
    assert zero_weight_extractors(w, prov) == {"a", "c"}
    assert zero_weight_extractors(w, prov, tol=1.0) == {"a", "b", "c"}


# -- stale_variants suppression rules ----------------------------------------

def test_stale_variants_never_selects_current_signature():
    by_name = {"n": ["sig-old", "sig-cur"], "m": ["sig-m"]}
    out = stale_variants(by_name, {"n", "m"},
                         {"n": "sig-cur", "m": "sig-m"})
    assert out == ["sig-old"]


def test_stale_variants_only_touches_original_names():
    by_name = {"n": ["old-n"], "m": ["old-m"]}
    # "m" is not original this iteration — its stored variant may belong
    # to a sibling sweep session and must be left alone.
    out = stale_variants(by_name, {"n"}, {"n": "cur-n", "m": "cur-m"})
    assert out == ["old-n"]


def test_stale_variants_deterministic_order():
    by_name = {"b": ["b1", "b2"], "a": ["a1"]}
    sigs = {"a": "a-cur", "b": "b-cur"}
    assert stale_variants(by_name, {"a", "b"}, sigs) == ["a1", "b1", "b2"]


# -- §6.6 purge end-to-end, with and without chunked manifests ---------------

def _session(path: str) -> IterativeSession:
    return IterativeSession(path,
                            engine=EngineConfig(policy=Policy.ALWAYS),
                            storage=StoreConfig(shared_budget=True))


def _chunk(desc):
    seed, n = desc
    return np.random.default_rng(seed).standard_normal(n)


def _chunked_wf(descs):
    wf = Workflow("purge")
    src = wf.source("src", lambda d=list(descs): [_chunk(x) for x in d],
                    chunks=list(descs))
    m = wf.extractor("m", lambda x: np.tanh(x), [src],
                     config="m", incremental="map")
    wf.output(m)
    return wf


def test_purge_removes_stale_variant_and_credits_bytes(tmp_path):
    def build(version):
        wf = Workflow("purge")
        src = wf.source("src",
                        lambda v=version: np.arange(64.0) * len(v),
                        config=version)
        wf.output(src)
        return wf

    sess = _session(str(tmp_path))
    sess.run(build("v1"))
    old_sig = compute_signatures(build("v1").build())["src"]
    assert sess.store.has_local(old_sig)
    rep = sess.run(build("v2"))
    assert rep.purged_bytes > 0
    assert not sess.store.has_local(old_sig)   # stale variant gone
    assert StorageLedger(sess.store.ledger_path).used() \
        == pytest.approx(float(sess.store.total_bytes()))


def test_delta_purge_keeps_still_valid_sibling_chunks(tmp_path):
    """An append makes the pre-append manifest a stale variant of "src"
    and "m"; the purge deletes those manifests *before* execution — but
    the prefix chunks they reference are exactly what the delta splice
    is about to reuse, so keep_chunks must spare them. If the cascade
    took them, every chunk would recompute and chunk_reused would be 0."""
    d0 = [(1, 30), (2, 30), (3, 30)]
    sess = _session(str(tmp_path))
    sess.run(_chunked_wf(d0))
    old_sigs = compute_signatures(_chunked_wf(d0).build())

    d1 = d0 + [(4, 30)]
    rep = sess.run(_chunked_wf(d1))
    # Stale pre-append manifests were purged — but freed 0 bytes: every
    # byte of a concat manifest lives in its chunks, and these chunks
    # are exactly the protected prefix of the imminent splice.
    for n in ("src", "m"):
        assert not sess.store.has_local(old_sigs[n])
    assert rep.purged_bytes == 0
    # … but their prefix chunks survived and were spliced, not recomputed.
    assert rep.execution.chunk_reused == {"src": 3, "m": 3}
    assert rep.execution.chunk_computed == {"src": 1, "m": 1}
    # Accounting stayed honest through purge + cascade + splice.
    assert StorageLedger(sess.store.ledger_path).used() \
        == pytest.approx(float(sess.store.total_bytes()))
    # No dangling references either direction: every referenced chunk
    # exists, every chunk entry is referenced (nothing for the GC).
    assert sess.store.gc_orphan_chunks(min_age_seconds=0.0) == (0, 0)


def test_sweep_mode_does_not_purge_sibling_variants(tmp_path):
    """purge_stale=False (sweep mode): a same-name different-config
    variant stays materialized — sibling sessions own it."""
    sess = IterativeSession(str(tmp_path),
                            engine=EngineConfig(policy=Policy.ALWAYS),
                            storage=StoreConfig(shared_budget=True,
                                                purge_stale=False))

    def build(version):
        wf = Workflow("sweep")
        src = wf.source("src", lambda v=version: np.arange(16.0),
                        config=version)
        wf.output(src)
        return wf

    sess.run(build("v1"))
    v1_sig = compute_signatures(build("v1").build())["src"]
    rep = sess.run(build("v2"))
    assert rep.purged_bytes == 0
    assert sess.store.has_local(v1_sig)
