"""Launch-layer units: input_specs coverage, collective parser, local
lower+compile of each step kind (1-device mesh — the 512-device sweep runs
via ``python -m repro.launch.dryrun``, not in the test suite)."""
import jax
import pytest

from repro import configs
from repro.launch import shapes as shapes_lib
from repro.launch.dryrun import collective_bytes_from_hlo, model_flops
from repro.launch.mesh import make_local_mesh


def test_cells_cover_assignment():
    total = 0
    for name in configs.ASSIGNED:
        cfg = configs.get(name)
        cs = shapes_lib.cells(cfg)
        assert "train_4k" in cs and "prefill_32k" in cs and "decode_32k" in cs
        total += len(cs)
    # 10 archs × 3 + long_500k for {mamba2, jamba, gemma3}
    assert total == 33


def test_long500k_policy():
    assert shapes_lib.long_ok(configs.get("mamba2-130m"))
    assert shapes_lib.long_ok(configs.get("jamba-v0.1-52b"))
    assert shapes_lib.long_ok(configs.get("gemma3-4b"))
    assert not shapes_lib.long_ok(configs.get("yi-9b"))
    assert not shapes_lib.long_ok(configs.get("whisper-medium"))


def test_input_specs_shapes():
    cfg = configs.get("internlm2-1.8b")
    state, batch = shapes_lib.input_specs(cfg, "train_4k")
    assert batch["tokens"].shape == (256, 4096)
    params, tok, cache = shapes_lib.input_specs(cfg, "decode_32k")
    assert tok.shape == (128, 1)
    assert cache["k"].shape == (24, 128, 32768, 8, 128)
    # no real arrays anywhere
    for leaf in jax.tree_util.tree_leaves(
            (state, batch, cache),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_vlm_audio_input_specs():
    vlm = configs.get("qwen2-vl-7b")
    _, batch = shapes_lib.input_specs(vlm, "train_4k")
    assert batch["vision_embeds"].shape == (256, 1024, vlm.d_model)
    assert batch["mrope_positions"].shape == (3, 256, 4096)
    aud = configs.get("whisper-medium")
    _, batch = shapes_lib.input_specs(aud, "train_4k")
    assert batch["frames"].shape == (256, 4096, aud.d_model)
    assert batch["tokens"].shape == (256, 448)


def test_collective_parser():
    hlo = """
  %ag = bf16[16,4096,1536]{2,1,0} all-gather(%p1), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[8,32]<=[256], to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[32,64]{1,0} dot(%a, %b)
"""
    out = collective_bytes_from_hlo(hlo)
    c = out["counts"]
    assert c["all-gather"] == 1 and c["all-reduce"] == 1
    assert c["reduce-scatter"] == 1 and c["collective-permute"] == 1
    ag = 16 * 4096 * 1536 * 2
    assert out["operand_bytes"]["all-gather"] == pytest.approx(ag / 16)
    assert out["operand_bytes"]["all-reduce"] == pytest.approx(1024 * 4)
    assert out["wire_bytes"]["all-reduce"] == pytest.approx(
        2 * 1024 * 4 * 31 / 32)
    assert out["operand_bytes"]["reduce-scatter"] == pytest.approx(64 * 4 * 4)
    assert out["operand_bytes"]["collective-permute"] == 8 * 128 * 2


def test_model_flops_conventions():
    cfg = configs.get("granite-moe-1b-a400m")
    train = model_flops(cfg, "train_4k")
    dec = model_flops(cfg, "decode_32k")
    assert train == pytest.approx(6 * cfg.active_param_count() * 256 * 4096)
    assert dec == pytest.approx(2 * cfg.active_param_count() * 128)


def test_fsdp_ruleset_build():
    """train_fsdp spreads the batch over (pod, data, model) and strips TP."""
    import dataclasses as dc
    cfg = configs.reduced(configs.get("internlm2-1.8b"))
    mesh = make_local_mesh()
    small = dc.replace(shapes_lib.SHAPES["train_4k"], seq=32, batch=4)
    old = shapes_lib.SHAPES["train_4k"]
    shapes_lib.SHAPES["train_4k"] = small
    try:
        fn, args, in_sh, out_sh, donate = shapes_lib.build_step(
            cfg, "train_4k", mesh, ruleset_name="train_fsdp")
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
        assert compiled is not None
    finally:
        shapes_lib.SHAPES["train_4k"] = old


@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_build_step_lowers_on_local_mesh(shape):
    """Lower+compile a REDUCED arch on the 1-device mesh — validates the
    build_step plumbing (shardings all collapse to replicated)."""
    cfg = configs.reduced(configs.get("granite-moe-1b-a400m"))
    # shrink the shape table for the local compile
    import dataclasses as dc
    small = dc.replace(shapes_lib.SHAPES[shape], seq=64,
                       batch=2 if shape != "decode_32k" else 2)
    mesh = make_local_mesh()
    old = shapes_lib.SHAPES[shape]
    shapes_lib.SHAPES[shape] = small
    try:
        fn, args, in_sh, out_sh, donate = shapes_lib.build_step(
            cfg, shape, mesh)
        with mesh:
            compiled = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate).lower(*args).compile()
        assert compiled is not None
    finally:
        shapes_lib.SHAPES[shape] = old
