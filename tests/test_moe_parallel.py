"""MoE parallel dispatch strategies: expert-TP (shard_map) and EP (a2a).

Correctness vs the einsum oracle with a tie-free router (near-tie top-k
flips under different compilation orders are inherent to MoE and are
excluded by construction), plus a *real* multi-device test in a subprocess
(8 forced host devices, mesh (2 data × 4 model), 8 experts → 2 per shard —
genuinely exercises the cross-shard all-to-all path).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.config import MoECfg
from repro.models.moe import moe_block, moe_block_a2a, moe_block_sharded, \
    moe_defs
from repro.models.params import P, init_params
from repro.launch.mesh import make_local_mesh


def _setup(key=0, n_tokens=16, d=32, e=8, k=2):
    mcfg = MoECfg(num_experts=e, top_k=k, expert_d_ff=16,
                  capacity_factor=float(e))
    defs = moe_defs(d, mcfg)
    defs = jax.tree_util.tree_map(
        lambda p: P(p.shape, p.axes, p.init, p.scale, jnp.float32),
        defs, is_leaf=lambda x: isinstance(x, P))
    p = init_params(defs, jax.random.PRNGKey(key))
    # tie-free router: strongly separated expert preferences
    p["router"] = p["router"] * 50.0
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (2, n_tokens, d),
                          jnp.float32)
    return mcfg, p, x


@pytest.mark.parametrize("impl", [moe_block_sharded, moe_block_a2a])
def test_parallel_impls_match_einsum(impl):
    mcfg, p, x = _setup()
    o1, a1 = moe_block(mcfg, p, x)
    mesh = make_local_mesh()
    with mesh:
        o2, a2 = jax.jit(lambda p, x: impl(mcfg, p, x))(p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-4)
    assert abs(float(a1) - float(a2)) < 1e-4


def test_a2a_falls_back_when_indivisible():
    """60 experts on a model axis it doesn't divide → expert-TP fallback."""
    mcfg, p, x = _setup(e=6, k=2)   # 6 % 1 == 0 on the local mesh, so force
    mesh = make_local_mesh()        # the check via a fake larger axis is
    with mesh:                      # covered in the subprocess test below
        o, _ = jax.jit(lambda p, x: moe_block_a2a(mcfg, p, x))(p, x)
    assert np.isfinite(np.asarray(o)).all()


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.models.config import MoECfg
    from repro.models.moe import moe_block, moe_block_a2a, moe_block_sharded, moe_defs
    from repro.models.params import P, init_params

    mcfg = MoECfg(num_experts=8, top_k=2, expert_d_ff=16,
                  capacity_factor=8.0)
    defs = moe_defs(32, mcfg)
    defs = jax.tree_util.tree_map(
        lambda p: P(p.shape, p.axes, p.init, p.scale, jnp.float32),
        defs, is_leaf=lambda x: isinstance(x, P))
    p = init_params(defs, jax.random.PRNGKey(0))
    p["router"] = p["router"] * 50.0
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
    expect, _ = moe_block(mcfg, p, x)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        for impl, name in ((moe_block_a2a, "a2a"),
                           (moe_block_sharded, "etp")):
            out, _ = jax.jit(lambda p, x: impl(mcfg, p, x))(p, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                       atol=1e-5, rtol=1e-4,
                                       err_msg=name)
    print("MOE_PARALLEL_OK")
""")


def test_a2a_on_real_multidevice_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), os.pardir))
    assert "MOE_PARALLEL_OK" in proc.stdout, proc.stdout + proc.stderr[-3000:]
