"""Model-layer properties: RoPE/M-RoPE, windows, MoE dispatch, pruning."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="dev dependency; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core.pruning import zero_weight_extractors
from repro.models import layers
from repro.models.config import MoECfg
from repro.models.moe import moe_block, moe_defs
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 8, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    y = layers.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """q·k after RoPE depends only on relative distance."""
    d = 64
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot_at(p1, p2):
        pos1 = jnp.full((1, 1), p1, jnp.int32)
        pos2 = jnp.full((1, 1), p2, jnp.int32)
        qr = layers.apply_rope(q, pos1, 10_000.0)
        kr = layers.apply_rope(k, pos2, 10_000.0)
        return float(jnp.sum(qr * kr))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


def test_mrope_equals_rope_when_positions_tied():
    """M-RoPE with t=h=w positions must reduce to standard RoPE."""
    x = jax.random.normal(KEY, (2, 8, 2, 64))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    mpos = jnp.broadcast_to(pos, (3, 2, 8))
    y1 = layers.apply_rope(x, pos, 10_000.0)
    y2 = layers.apply_rope(x, mpos, 10_000.0, mrope_sections=(8, 12, 12))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_sliding_window_masks_past():
    """With window w, token i must ignore tokens < i-w+1."""
    b, s, h, d = 1, 32, 2, 32
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out_w = layers.gqa_attention(q, k, v, pos, pos, causal=True, window=4)
    # perturb k/v far outside every window of the last query
    k2 = k.at[:, :8].add(100.0)
    v2 = v.at[:, :8].add(100.0)
    out_w2 = layers.gqa_attention(q, k2, v2, pos, pos, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(out_w[:, -1]),
                               np.asarray(out_w2[:, -1]), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3))
def test_moe_combine_weights_sum(n_tokens_log, k):
    """MoE with capacity ≫ tokens must route every token (no drops), and
    the output must be the gate-weighted sum of expert outputs."""
    e = 4
    k = min(k, e)
    n = 2 ** n_tokens_log
    mcfg = MoECfg(num_experts=e, top_k=k, expert_d_ff=16,
                  capacity_factor=float(e))  # huge capacity → no drops
    defs = moe_defs(8, mcfg)
    p = init_params(defs, KEY)
    x = jax.random.normal(KEY, (1, n, 8), jnp.float32)
    out, aux = moe_block(mcfg, p, x)
    assert out.shape == (1, n, 8)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """capacity_factor ≪ 1 must drop tokens (outputs become zero-ish)."""
    e, k = 4, 1
    mcfg_full = MoECfg(num_experts=e, top_k=k, expert_d_ff=16,
                       capacity_factor=4.0)
    mcfg_tiny = MoECfg(num_experts=e, top_k=k, expert_d_ff=16,
                       capacity_factor=0.05)
    defs = moe_defs(8, mcfg_full)
    p = init_params(defs, KEY)
    x = jax.random.normal(KEY, (1, 64, 8), jnp.float32)
    out_full, _ = moe_block(mcfg_full, p, x)
    out_tiny, _ = moe_block(mcfg_tiny, p, x)
    assert float(jnp.sum(jnp.abs(out_tiny))) < float(jnp.sum(jnp.abs(out_full)))


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 80), st.integers(8, 130), st.booleans(),
       st.sampled_from([None, 4, 16]))
def test_chunked_attention_matches_reference(sq, sk, causal, window):
    """Property: the flash-style chunked XLA attention (arbitrary Sq/Sk,
    padding path) must match the dense reference."""
    b, h, kv, d = 1, 2, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(sq * 131 + sk), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    off = max(sk - sq, 0)
    qp = off + jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    kp = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32), (b, sk))
    o1 = layers.gqa_attention(q, k, v, qp, kp, causal=causal, window=window,
                              impl="reference")
    o2 = layers.gqa_attention(q, k, v, qp, kp, causal=causal, window=window,
                              impl="chunked")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_zero_weight_extractor_pruning():
    w = np.array([0.0, 0.0, 0.5, 1e-12, 2.0])
    prov = {"dead": [0, 1], "half": [2, 3], "live": [4]}
    assert zero_weight_extractors(w, prov) == {"dead"}
