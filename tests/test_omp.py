"""OPT-MAT-PLAN: Algorithm 2 threshold, budget, policies, paper §5.3 notes."""

from repro.core.dag import DAG, Node, State
from repro.core.omp import Materializer, Policy, cumulative_runtime


def chain(n):
    return DAG([Node(f"n{i}", None, (f"n{i-1}",) if i else (),
                     is_output=(i == n - 1)) for i in range(n)])


def test_threshold_rule():
    dag = chain(3)
    states = {f"n{i}": State.COMPUTE for i in range(3)}
    runtime = {"n0": 5.0, "n1": 5.0, "n2": 0.1}
    m = Materializer(policy=Policy.OPT)
    # C(n1) = 10; 2·l = 4 < 10 → materialize
    d = m.decide(dag, "n1", states, runtime, est_load_seconds=2.0,
                 est_bytes=10)
    assert d.materialize
    # 2·l = 12 >= 10 → skip
    d = m.decide(dag, "n1", states, runtime, est_load_seconds=6.0,
                 est_bytes=10)
    assert not d.materialize


def test_cumulative_runtime_counts_loaded_and_computed():
    dag = chain(3)
    states = {"n0": State.LOAD, "n1": State.COMPUTE, "n2": State.COMPUTE}
    runtime = {"n0": 1.0, "n1": 2.0, "n2": 4.0}
    assert cumulative_runtime(dag, "n2", states, runtime) == 7.0


def test_storage_budget():
    dag = chain(2)
    states = {"n0": State.COMPUTE, "n1": State.COMPUTE}
    runtime = {"n0": 100.0, "n1": 100.0}
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=15)
    assert m.decide(dag, "n0", states, runtime, 0.01, est_bytes=10).materialize
    # second one exceeds the budget
    assert not m.decide(dag, "n1", states, runtime, 0.01,
                        est_bytes=10).materialize
    m.release(10)
    assert m.decide(dag, "n1", states, runtime, 0.01, est_bytes=10).materialize


def test_policies():
    dag = chain(2)
    states = {"n0": State.COMPUTE, "n1": State.COMPUTE}
    runtime = {"n0": 0.001, "n1": 0.001}
    am = Materializer(policy=Policy.ALWAYS)
    nm = Materializer(policy=Policy.NEVER)
    assert am.decide(dag, "n0", states, runtime, 100.0, 1).materialize
    assert not nm.decide(dag, "n0", states, runtime, 0.0, 1).materialize


def test_nondeterministic_materialization_policy():
    dag = DAG([Node("nd", None, (), deterministic=False),
               Node("out", None, ("nd",), is_output=True)])
    states = {"nd": State.COMPUTE, "out": State.COMPUTE}
    # OPT never wastes a write on a non-reusable node…
    m = Materializer(policy=Policy.OPT)
    assert not m.decide(dag, "nd", states, {"nd": 100.0}, 0.0, 1).materialize
    # …but the paper's AM (DeepDive-style) does — that waste is the point.
    am = Materializer(policy=Policy.ALWAYS)
    assert am.decide(dag, "nd", states, {"nd": 100.0}, 0.0, 1).materialize


def test_amortized_horizon_materializes_more():
    """Beyond-paper: with an expected-reuse horizon > 1 the threshold drops
    toward l < C (the paper's 2l < C assumes a single future reuse)."""
    dag = chain(2)
    states = {"n0": State.COMPUTE, "n1": State.COMPUTE}
    runtime = {"n0": 10.0, "n1": 0.1}
    # l = 6: paper rule 2·6 = 12 > C = 10 → skip…
    m1 = Materializer(policy=Policy.OPT, horizon=1.0)
    assert not m1.decide(dag, "n0", states, runtime, 6.0, 1).materialize
    # …but amortized over 5 iterations (1.2·6 = 7.2 < 10) → materialize
    m5 = Materializer(policy=Policy.OPT, horizon=5.0)
    assert m5.decide(dag, "n0", states, runtime, 6.0, 1).materialize


def test_multiplicity_supersedes_static_horizon():
    """ISSUE 3: observed per-signature multiplicity (the session server's
    live cross-client map) lifts the amortization for exactly the shared
    signatures, leaving unshared ones at the static-horizon threshold."""
    dag = chain(2)
    states = {"n0": State.COMPUTE, "n1": State.COMPUTE}
    runtime = {"n0": 10.0, "n1": 0.1}
    mult = {"shared-sig": 4.0}
    m = Materializer(policy=Policy.OPT, horizon=1.0,
                     multiplicity=lambda sig: mult.get(sig, 0.0))
    # l = 6, C = 10: paper threshold 2·6 = 12 > 10 → skip when unshared…
    d = m.decide(dag, "n0", states, runtime, 6.0, 1, sig="lone-sig")
    assert not d.materialize
    # …but 4 live siblings amortize it: (1 + 1/4)·6 = 7.5 < 10 → persist
    d = m.decide(dag, "n0", states, runtime, 6.0, 1, sig="shared-sig")
    assert d.materialize
    # the static horizon stays an explicit floor over the observed map
    m_floor = Materializer(policy=Policy.OPT, horizon=5.0,
                           multiplicity=lambda sig: 0.0)
    assert m_floor.effective_horizon("anything") == 5.0
    d = m_floor.decide(dag, "n0", states, runtime, 6.0, 1, sig="lone-sig")
    assert d.materialize


def test_paper_pathological_chain_documented():
    """§5.3 'Limitations of Streaming OMP': chain with l_i = i, c_i = 3 —
    Algorithm 2 materializes every node (storage O(m²)). We reproduce the
    behavior (it is the paper's documented limitation, not a bug)."""
    n = 8
    dag = chain(n)
    states = {f"n{i}": State.COMPUTE for i in range(n)}
    runtime = {f"n{i}": 3.0 for i in range(n)}
    m = Materializer(policy=Policy.OPT)
    decisions = []
    for i in range(2, n):       # C(n_i) = 3(i+1); 2·l = 2i < 3i+3 always
        d = m.decide(dag, f"n{i}", states, runtime,
                     est_load_seconds=float(i), est_bytes=1)
        decisions.append(d.materialize)
    assert all(decisions)
