"""Fleet-router suite (ISSUE 10): placement, protocol parity, warm reruns.

The chaos-side router tests (shard death mid-job, rejoin rebalance
fractions) live in ``tests/test_faults.py``; this file covers the
steady-state contract:

* rendezvous hashing is a pure function — independent router instances
  (and independent processes) agree on placement, and arms sharing a
  workflow prefix share a shard;
* the router satisfies the :class:`~repro.serve.client.Client` protocol
  and ``connect()`` passes it through unchanged, so drivers written
  against one server work against a fleet;
* warm-shard reruns are pure cache hits: consistent-hash routing sends
  a repeated submission back to the shard that already holds its
  prefix, so nothing is recomputed (the claim ``bench_multitenant``
  quantifies).
"""
import threading

import numpy as np
import pytest

from repro.core.config import EngineConfig
from repro.core.locking import HAVE_FLOCK
from repro.core.workflow import Workflow
from repro.serve import (FleetRouter, SessionServer, connect, rendezvous)
from repro.serve.client import Client

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


class Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self.counts.get(name, 0)


def build_family(family: str, reg: float, calls: Calls | None = None,
                 work: int = 600) -> Workflow:
    """src → feat (slow, shared within a family) → model(reg) → eval."""
    def count(name):
        if calls is not None:
            calls.hit(name)

    wf = Workflow(f"{family}-{reg}")
    src = wf.source(
        "src",
        lambda: np.arange(4096, dtype=np.float64).reshape(64, 64),
        config=("v1", family))

    def featurize(m):
        count(f"feat_{family}")
        acc = m.copy()
        for _ in range(work):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config=("feat", family))
    model = wf.learner(
        "model", lambda z, r=reg: float(np.sum(z * z)) * r,
        [feat], config=("LR", reg))
    out = wf.reducer("eval", lambda m: {"score": m}, [model],
                     config=("eval",))
    wf.output(out)
    return wf


def _registry(calls=None, work=600):
    return {"fam": lambda family, reg:
            build_family(family, reg, calls, work=work)}


def _fleet(tmp_path, n=2, calls=None, **kw):
    servers = {}
    for i in range(n):
        sid = f"s{i}"
        servers[sid] = SessionServer(
            str(tmp_path / sid), registry=_registry(calls),
            engine=EngineConfig(n_sessions=2), poll_interval=0.01, **kw)
    return servers


# ---------------------------------------------------------------------------
# placement: pure, deterministic, prefix-affine
# ---------------------------------------------------------------------------
def test_rendezvous_is_pure_and_total():
    ids = ["s0", "s1", "s2", "s3"]
    keys = [f"key-{i}" for i in range(64)]
    a = [rendezvous(ids, k) for k in keys]
    b = [rendezvous(reversed(ids), k) for k in keys]   # order-insensitive
    assert a == b
    assert set(a) == set(ids)        # 64 keys land on all 4 shards
    with pytest.raises(LookupError):
        rendezvous([], "k")


def test_route_keys_are_prefix_affine(tmp_path):
    """Arms of one family share a route key (same source signatures);
    different families get different keys; two independent router
    instances agree on every placement."""
    servers = _fleet(tmp_path, n=2)
    try:
        r1 = FleetRouter(servers, registry=_registry())
        r2 = FleetRouter(servers, registry=_registry())
        ka1 = r1.route_key("fam", {"family": "a", "reg": 0.1})
        ka2 = r1.route_key("fam", {"family": "a", "reg": 0.9})
        kb = r1.route_key("fam", {"family": "b", "reg": 0.1})
        assert ka1 == ka2            # same family → same prefix → same key
        assert ka1 != kb
        for key in (ka1, kb):
            assert r1.shard_for(key) == r2.shard_for(key)
        # without a registry entry the key degrades to (workflow, params)
        # — still deterministic, still total
        bare = FleetRouter(servers)
        k1 = bare.route_key("fam", {"family": "a", "reg": 0.1})
        assert k1 == bare.route_key("fam", {"family": "a", "reg": 0.1})
        assert k1 != bare.route_key("fam", {"family": "a", "reg": 0.2})
    finally:
        for srv in servers.values():
            srv.shutdown()


def test_random_route_is_seeded(tmp_path):
    """The benchmark's control arm: same seed → same placement stream."""
    servers = _fleet(tmp_path, n=2)
    try:
        picks = []
        for _ in range(2):
            r = FleetRouter(servers, registry=_registry(),
                            route="random", seed=7)
            picks.append([r._pick_shard("k") for _ in range(16)])
        assert picks[0] == picks[1]
        assert len(set(picks[0])) == 2      # actually spreads
        with pytest.raises(ValueError, match="unknown route mode"):
            FleetRouter(servers, route="roulette")
    finally:
        for srv in servers.values():
            srv.shutdown()


# ---------------------------------------------------------------------------
# Client-protocol parity
# ---------------------------------------------------------------------------
def test_router_speaks_the_client_protocol(tmp_path):
    """submit/wait/estimate/job/cancel/forget/status/hello through the
    router behave like a single server; ``connect()`` passes a router
    through unchanged."""
    calls = Calls()
    servers = _fleet(tmp_path, n=2, calls=calls)
    try:
        router = FleetRouter(servers, registry=_registry(calls))
        assert isinstance(router, Client)
        assert connect(router) is router

        hello = router.hello()
        assert hello["server"] == "helix-fleet-router"
        assert hello["workflows"] == ["fam"]

        est = router.estimate("fam", {"family": "a", "reg": 0.1})
        assert est["shard"] in servers and est["total_s"] >= 0.0

        job = router.submit("fam", {"family": "a", "reg": 0.1})
        out = router.wait(job, timeout=60.0)
        assert out["status"] == "done"
        assert out["shard"] == router.shard_for(
            router.route_key("fam", {"family": "a", "reg": 0.1}))
        assert "score" in out["outputs"]["eval"]

        assert router.job(job)["status"] == "done"
        assert router.cancel(job) is False          # already finished
        assert router.forget(job) is True
        assert router.forget(job) is False          # record dropped

        snap = router.status()
        assert snap["router"] and snap["failovers"] == 0
        assert sorted(snap["shards"]) == ["s0", "s1"]
        assert snap["live_shards"] == ["s0", "s1"]
    finally:
        for srv in servers.values():
            srv.shutdown()


def test_router_drain_and_shutdown(tmp_path):
    servers = _fleet(tmp_path, n=2)
    try:
        with FleetRouter(servers, registry=_registry()) as router:
            router.submit("fam", {"family": "a", "reg": 0.1})
            assert router.drain(timeout=60.0)
            assert sorted(router.shutdown()["stopped"]) == ["s0", "s1"]
        for srv in servers.values():
            assert not srv._accepting
    finally:
        for srv in servers.values():
            srv.shutdown()          # idempotent


# ---------------------------------------------------------------------------
# warm-shard reruns: the consistent-hash payoff
# ---------------------------------------------------------------------------
def test_warm_rerun_recomputes_nothing(tmp_path):
    """Hash routing sends a repeat submission back to the shard that
    already holds its prefix: the rerun computes zero nodes fleet-wide.
    A fresh router instance (new process, same fleet) gets the same warm
    hit — placement is state-free."""
    calls = Calls()
    servers = _fleet(tmp_path, n=2, calls=calls)
    try:
        arms = [("a", 0.1), ("a", 0.4), ("b", 0.2), ("c", 0.3)]
        router = FleetRouter(servers, registry=_registry(calls))
        jobs = [router.submit("fam", {"family": f, "reg": r})
                for f, r in arms]
        for job in jobs:
            assert router.wait(job, timeout=60.0)["status"] == "done"
        warm = {f: calls.get(f"feat_{f}") for f in "abc"}
        assert warm == {"a": 1, "b": 1, "c": 1}

        # rerun through a *different* router instance: all cache hits
        rerun = FleetRouter(servers, registry=_registry(calls))
        jobs = [rerun.submit("fam", {"family": f, "reg": r})
                for f, r in arms]
        for job in jobs:
            assert rerun.wait(job, timeout=60.0)["status"] == "done"
        assert {f: calls.get(f"feat_{f}") for f in "abc"} == warm
    finally:
        for srv in servers.values():
            srv.shutdown()
