"""Cross-process store concurrency: compute leases, the on-disk index,
the shared budget ledger, and merge-on-flush statistics.

The multiprocessing tests spawn real OS processes against one store root —
the scenario the fleet hardening exists for (N sweep workers / sessions on
one filesystem). Everything must hold with zero shared Python state.
"""
import multiprocessing as mp
import os
import time

import numpy as np
import pytest

from repro.core.costs import CostModel
from repro.core.locking import (FileLock, HAVE_FLOCK, SharedEwma,
                                StorageLedger)
from repro.core.store import Store

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


def _sig_value(sig: str) -> np.ndarray:
    return np.full(256, float(int(sig, 16) % 97))


SIGS = [f"{i:02x}a7" for i in range(6)]


def _lease_worker(root: str, order: list[str], budget: float, q) -> None:
    """One fleet member: compute-or-wait every signature (the executor's
    dedupe loop, distilled), persisting under the shared budget ledger."""
    try:
        store = Store(root)
        ledger = StorageLedger(store.ledger_path)
        computed, loaded = [], []
        for sig in order:
            while True:
                if store.has(sig):
                    value, _ = store.load(sig)
                    assert np.array_equal(value, _sig_value(sig)), \
                        f"corrupt read for {sig}"
                    loaded.append(sig)
                    break
                lease = store.acquire_compute(sig)
                if lease is not None:
                    try:
                        time.sleep(0.05)  # the "expensive" compute
                        if ledger.try_reserve(_sig_value(sig).nbytes,
                                              budget):
                            store.save(sig, f"node-{sig}", _sig_value(sig))
                        computed.append(sig)
                    finally:
                        lease.release()
                    break
                if not store.wait_compute(sig, timeout=30):
                    raise TimeoutError(f"lease wait timed out for {sig}")
        q.put(("ok", os.getpid(), computed, loaded))
    except BaseException as e:  # pragma: no cover - failure path
        q.put(("err", os.getpid(), repr(e), []))


def _churn_worker(root: str, seed: int, budget: float, q) -> None:
    """Hammer save/load/delete on a small signature set under the shared
    ledger; every observation must be a whole, uncorrupted entry."""
    try:
        rng = np.random.default_rng(seed)
        store = Store(root)
        ledger = StorageLedger(store.ledger_path)
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            sig = SIGS[int(rng.integers(len(SIGS)))]
            op = rng.integers(3)
            if op == 0:
                if ledger.try_reserve(_sig_value(sig).nbytes, budget):
                    store.save(sig, f"node-{sig}", _sig_value(sig))
            elif op == 1:
                try:
                    value, _ = store.load(sig)
                    assert np.array_equal(value, _sig_value(sig))
                except FileNotFoundError:
                    pass  # concurrently deleted — acceptable
            else:
                freed = store.delete(sig)
                if freed:
                    ledger.release(freed)
        q.put(("ok", os.getpid(), [], []))
    except BaseException as e:  # pragma: no cover - failure path
        q.put(("err", os.getpid(), repr(e), []))


def _collect(procs, q):
    results = []
    for _ in procs:
        results.append(q.get(timeout=120))
    for p in procs:
        p.join(timeout=30)
    errs = [r for r in results if r[0] == "err"]
    assert not errs, errs
    return results


@pytest.mark.parametrize("n_procs", [4])
def test_multiprocess_compute_once_and_index_consistent(tmp_path, n_procs):
    """N processes race the same signatures: each signature is computed by
    exactly one process fleet-wide, every load observes whole data, and
    the on-disk index ends exactly in sync with the filesystem."""
    root = str(tmp_path / "store")
    Store(root)  # pre-create so children skip racing the initial mkdir
    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    rng = np.random.default_rng(0)
    procs = []
    for i in range(n_procs):
        order = list(rng.permutation(SIGS))
        p = ctx.Process(target=_lease_worker,
                        args=(root, order, float("inf"), q))
        p.start()
        procs.append(p)
    results = _collect(procs, q)

    all_computed = [sig for r in results for sig in r[2]]
    assert sorted(all_computed) == sorted(SIGS), (
        f"double-compute or miss: {all_computed}")
    store = Store(root)
    assert set(store.entries()) == set(SIGS)
    # index == filesystem, byte for byte
    scan = store._scan_entries()
    assert set(scan) == set(store.entries())
    assert store.total_bytes() == sum(m["nbytes"] for m in scan.values())
    for sig in SIGS:
        value, _ = store.load(sig)
        assert np.array_equal(value, _sig_value(sig))


def test_multiprocess_churn_no_corruption_budget_respected(tmp_path):
    """Racing save/load/delete across processes under one shared budget:
    no torn entries, index consistent, ledger never exceeds the budget."""
    root = str(tmp_path / "store")
    Store(root)
    budget = 3.5 * 256 * 8  # fits ~3 of the 6 entries
    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    procs = [ctx.Process(target=_churn_worker, args=(root, i, budget, q))
             for i in range(4)]
    for p in procs:
        p.start()
    _collect(procs, q)

    store = Store(root)
    scan = store._scan_entries()
    assert set(scan) == set(store.entries())
    for sig in scan:
        value, _ = store.load(sig)
        assert np.array_equal(value, _sig_value(sig))
    ledger = StorageLedger(store.ledger_path)
    assert 0.0 <= ledger.used() <= budget
    # the churn always reserved before saving, so what survived fits too
    assert store.total_bytes() <= budget


# ---------------------------------------------------------------------------
# single-process unit coverage of the fleet primitives
# ---------------------------------------------------------------------------
def test_filelock_excludes_within_process(tmp_path):
    path = str(tmp_path / "x.lock")
    a = FileLock(path)
    assert a.acquire(blocking=False)
    b = FileLock(path)
    assert not b.acquire(blocking=False)
    assert b.locked_elsewhere()
    a.release()
    assert b.acquire(blocking=False)
    b.release()


def test_filelock_shared_readers_coexist(tmp_path):
    path = str(tmp_path / "x.lock")
    r1, r2 = FileLock(path, shared=True), FileLock(path, shared=True)
    assert r1.acquire(blocking=False) and r2.acquire(blocking=False)
    w = FileLock(path)
    assert not w.acquire(blocking=False)   # writers excluded by readers
    r1.release(), r2.release()
    assert w.acquire(blocking=False)
    w.release()


def test_compute_lease_waiters_and_takeover(tmp_path):
    store = Store(str(tmp_path))
    lease = store.acquire_compute("ab01")
    assert lease is not None
    assert store.acquire_compute("ab01") is None   # held
    assert lease.waiters() == 0
    assert not store.wait_compute("ab01", timeout=0.05)  # times out
    lease.release()
    assert store.wait_compute("ab01", timeout=0.05)      # free now
    lease2 = store.acquire_compute("ab01")               # takeover
    assert lease2 is not None
    lease2.release()


def test_delete_respects_live_leases(tmp_path):
    store = Store(str(tmp_path))
    store.save("cd02", "x", np.zeros(16))
    pin = store.acquire_read("cd02")
    assert pin is not None
    assert store.delete("cd02") == 0          # pinned: eviction refused
    assert store.has("cd02")
    pin.release()
    assert store.delete("cd02") > 0
    assert not store.has("cd02")


def test_index_heals_after_out_of_band_changes(tmp_path):
    store = Store(str(tmp_path))
    store.save("ee03", "x", np.zeros(16))
    # simulate a crashed process that published a dir but died pre-index
    other = Store(str(tmp_path))
    other.save("ee04", "y", np.zeros(16))
    os.remove(other.index_path)
    healed = Store(str(tmp_path), heal=True)   # forced heal rebuilds
    assert set(healed.entries()) == {"ee03", "ee04"}
    # and even without healing, a missing index rebuilds on demand
    os.remove(healed.index_path)
    lazy = Store(str(tmp_path), heal=False)
    assert set(lazy.entries()) == {"ee03", "ee04"}


def test_fleet_metadata_reaped(tmp_path):
    """Lock/lease files of long-gone signatures, dead waiter markers, and
    crashed atomic-publish temps are pruned on reopen; metadata of live
    entries and recent signatures survives."""
    import subprocess

    store = Store(str(tmp_path))
    store.save("aa10", "keep", np.zeros(8))
    lease = store.acquire_compute("aa10")
    lease.release()
    # cold signature without an entry: aged lock + lease files
    old = time.time() - 2 * Store._TMP_ORPHAN_SECONDS
    for path in (store._entry_lock("bb20").path, store._lease_path("bb20"),
                 store._entry_lock("aa10").path, store._lease_path("aa10")):
        open(path, "a").close()
        os.utime(path, (old, old))
    # dead waiter marker + crashed update_json temp
    proc = subprocess.Popen(["true"])
    proc.wait()
    marker = os.path.join(store._fleet_dir("leases"), "cc30.w-deadbeef")
    with open(marker, "w") as f:
        f.write(str(proc.pid))
    crash_tmp = store.index_path + f".tmp-{proc.pid}-1"
    open(crash_tmp, "w").close()

    store2 = Store(str(tmp_path), heal=True)
    assert not os.path.exists(store2._lease_path("bb20"))
    assert not os.path.exists(store2._entry_lock("bb20").path)
    assert not os.path.exists(marker)
    assert not os.path.exists(crash_tmp)
    # live entry's metadata kept even though the files are old
    assert os.path.exists(store2._lease_path("aa10"))
    value, _ = store2.load("aa10")
    assert np.array_equal(value, np.zeros(8))
    # and the lease protocol still works after the sweep
    lease = store2.acquire_compute("bb20")
    assert lease is not None
    lease.release()


def test_storage_ledger_reserve_release(tmp_path):
    ledger = StorageLedger(str(tmp_path / "ledger.json"))
    assert ledger.try_reserve(100, budget=150)
    assert not ledger.try_reserve(100, budget=150)  # would exceed
    assert ledger.used() == 100
    ledger.release(40)
    assert ledger.try_reserve(90, budget=150)
    assert ledger.used() == 150


def test_shared_ewma_merges_across_instances(tmp_path):
    path = str(tmp_path / "bw.json")
    a = SharedEwma(path, alpha=0.5, flush_interval=0.0)
    b = SharedEwma(path, alpha=0.5, flush_interval=0.0)
    assert a.update("read", 100.0) == pytest.approx(100.0)
    merged = b.update("read", 200.0)   # blends with a's on-disk value
    assert merged == pytest.approx(150.0)
    fresh = SharedEwma(path)
    assert fresh.get("read") == pytest.approx(150.0)


def test_shared_ewma_throttles_disk_flushes(tmp_path):
    path = str(tmp_path / "bw.json")
    ewma = SharedEwma(path, alpha=0.5, flush_interval=3600.0)
    ewma.update("read", 100.0)          # first observation flushes
    mtime = os.stat(path).st_mtime_ns
    for _ in range(50):
        ewma.update("read", 200.0)      # in-memory only
    assert os.stat(path).st_mtime_ns == mtime
    assert ewma.get("read") > 100.0     # local estimate still advances


def test_cost_model_merge_on_flush(tmp_path):
    path = str(tmp_path / "costs.json")
    a, b = CostModel(path), CostModel(path)
    a.record("s1", compute_seconds=1.0)
    a.record("s2", compute_seconds=4.0)
    b.record("s2", compute_seconds=2.0)
    b.record("s3", compute_seconds=3.0)
    a.save()
    b.save()   # must not clobber a's flush
    fresh = CostModel(path)
    assert fresh.seen == {"s1", "s2", "s3"}
    assert fresh.compute_s["s1"] == 1.0
    assert fresh.compute_s["s3"] == 3.0
    # overlapping key was blended, not overwritten
    assert 2.0 <= fresh.compute_s["s2"] <= 4.0


def test_cost_model_stale_reads_not_remerged(tmp_path):
    """Values a session merely *read* at init must not dilute a sibling's
    fresher measurement when the reader flushes."""
    path = str(tmp_path / "costs.json")
    seed = CostModel(path)
    seed.record("x", compute_seconds=100.0)
    seed.save()
    reader = CostModel(path)       # loads x=100 but never measures it
    sibling = CostModel(path)
    sibling.record("x", compute_seconds=2.0)
    sibling.save()                 # fresh measurement lands on disk
    reader.record("y", compute_seconds=1.0)
    reader.save()                  # must not drag x back toward 100
    fresh = CostModel(path)
    assert fresh.compute_s["x"] < 50.0
    assert fresh.compute_s["y"] == 1.0


def test_save_reports_replaced_and_ledger_self_corrects(tmp_path):
    """Two fleet members racing one signature each reserve budget; the
    overwrite is reported so the loser's reservation can be credited
    back — the ledger converges to one entry's worth."""
    store = Store(str(tmp_path))
    ledger = StorageLedger(store.ledger_path)
    value = np.zeros(256)
    budget = 10 * value.nbytes
    assert ledger.try_reserve(value.nbytes, budget)
    info1 = store.save("ff01", "x", value)
    assert not info1.replaced
    assert ledger.try_reserve(value.nbytes, budget)
    info2 = store.save("ff01", "x", value)
    assert info2.replaced
    ledger.release(value.nbytes)   # what the executor does on replaced
    assert ledger.used() == value.nbytes
    assert store.total_bytes() == value.nbytes
