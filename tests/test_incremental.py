"""Differential-testing oracle for incremental recomputation (chunks.py).

The property under test: for ANY workflow DAG mixing incrementalizable
(map / union / assoc_reduce) and opaque operators, and ANY sequence of
data deltas (append, append, full-change), the chunk-spliced incremental
session produces *bit-identical* outputs to a cold full recompute in a
fresh store — at every step. Alongside bit-identity the oracle checks
the two accounting invariants:

* chunk work == missing chunks: for every chunk-planned node (except
  union, which never invokes its fn), ``chunk_computed[n]`` equals
  exactly the number of its plan's chunk signatures absent from the
  store before the run — on a pure append that is the appended chunks;
* ledger == disk after every splice (fleet budget honesty).

A seeded plain-numpy driver runs everywhere; hypothesis (a dev/CI-only
dependency, see requirements-dev.txt) drives the same machinery over a
wider random space when installed — ``--hypothesis-profile=ci-deep``
(registered in conftest.py) deepens it for the nightly tier-2 job.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core.config import EngineConfig, StoreConfig
from repro.core.locking import StorageLedger
from repro.core.omp import Policy
from repro.core.session import IterativeSession
from repro.core.signature import compute_chunk_signatures, compute_signatures
from repro.core.workflow import Workflow

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# random-DAG generator: specs are plain data so the same spec list builds
# the same workflow for the incremental and the cold session
# ---------------------------------------------------------------------------
def _chunk_value(desc):
    seed, n = desc
    return np.random.default_rng(seed).standard_normal(n)


def make_specs(rng: np.random.Generator, n_ops: int) -> list[dict]:
    """A random operator list over 1-2 chunked sources.

    Each spec is ``{name, op, parents, a, b}``; ``op`` is one of
    source/map/union/assoc_reduce/opaque. The generator tracks which
    nodes are chunked (concat-mode) so unions only take chunked parents
    and maps take one chunked parent plus (sometimes) one flat
    broadcast parent — anything else would (correctly) just fail the
    plan gates and fall back to opaque execution, which the oracle also
    covers via explicit opaque ops.
    """
    n_src = int(rng.integers(1, 3))
    specs = [{"name": f"src{i}", "op": "source", "parents": ()}
             for i in range(n_src)]
    chunked = [s["name"] for s in specs]
    flat: list[str] = []
    for i in range(n_ops):
        name = f"n{i}"
        a = float(rng.uniform(0.5, 2.0))
        b = float(rng.uniform(-1.0, 1.0))
        op = str(rng.choice(
            ["map", "map", "union", "assoc_reduce", "opaque", "opaque"]))
        if op == "union" and len(chunked) < 2:
            op = "map"
        if op == "map":
            parents = [str(rng.choice(chunked))]
            if flat and rng.random() < 0.4:
                parents.append(str(rng.choice(flat)))
            chunked.append(name)
        elif op == "union":
            parents = list(rng.choice(chunked, size=2, replace=False))
            chunked.append(name)
        elif op == "assoc_reduce":
            parents = [str(rng.choice(chunked))]
            flat.append(name)
        else:  # opaque: any parents, output flat
            pool = chunked + flat
            parents = [str(p) for p in
                       rng.choice(pool, size=min(2, len(pool)),
                                  replace=False)]
            flat.append(name)
        specs.append({"name": name, "op": op, "parents": tuple(parents),
                      "a": a, "b": b})
    return specs


def build_workflow(specs: list[dict],
                   descs: dict[str, list[tuple]]) -> Workflow:
    wf = Workflow("oracle")
    refs: dict[str, object] = {}
    for s in specs:
        name, op = s["name"], s["op"]
        if op == "source":
            d = list(descs[name])
            refs[name] = wf.source(
                name, lambda d=d: [_chunk_value(x) for x in d], chunks=d)
            continue
        parents = [refs[p] for p in s["parents"]]
        a, b = s["a"], s["b"]
        if op == "map":
            if len(parents) == 2:
                fn = (lambda x, f, a=a, b=b:
                      np.sin(a * x) + b + float(np.mean(f)))
            else:
                fn = lambda x, a=a, b=b: np.sin(a * x) + b
            refs[name] = wf.extractor(name, fn, parents,
                                      config=("m", a, b),
                                      incremental="map")
        elif op == "union":
            refs[name] = wf.extractor(
                name, lambda *vs: np.concatenate(vs, axis=0), parents,
                config="u", incremental="union")
        elif op == "assoc_reduce":
            fn = ((lambda x: np.sum(x, axis=0)) if a < 1.25
                  else (lambda x: np.max(x, axis=0)))
            refs[name] = wf.reducer(name, fn, parents,
                                    config=("r", a < 1.25),
                                    incremental="assoc_reduce")
        else:  # opaque: global state (mean over all rows) — not a map
            refs[name] = wf.synthesizer(
                name,
                lambda *vs, a=a: np.asarray(
                    [a * sum(float(np.sum(np.asarray(v))) for v in vs),
                     sum(float(np.mean(np.asarray(v))) for v in vs)]),
                parents, config=("o", a))
    consumed = {p for s in specs for p in s["parents"]}
    for s in specs:
        if s["name"] not in consumed:
            wf.output(refs[s["name"]])
    return wf


def _session(workdir: str) -> IterativeSession:
    return IterativeSession(workdir,
                            engine=EngineConfig(policy=Policy.ALWAYS),
                            storage=StoreConfig(shared_budget=True))


def _assert_bit_identical(a, b, ctx: str) -> None:
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype, f"{ctx}: dtype {a.dtype} != {b.dtype}"
    assert a.shape == b.shape, f"{ctx}: shape {a.shape} != {b.shape}"
    assert a.tobytes() == b.tobytes(), f"{ctx}: bytes differ"


def run_oracle(tmp_path, seed: int, n_ops: int = 6,
               deltas: tuple[str, ...] = ("append", "append",
                                          "full-change")) -> None:
    """One full differential run: build a random DAG, apply the delta
    sequence, and at every step compare the incremental session to a
    cold recompute while checking the chunk- and ledger-accounting
    invariants."""
    rng = np.random.default_rng(seed)
    specs = make_specs(rng, n_ops)
    sources = [s["name"] for s in specs if s["op"] == "source"]
    base = {src: 1000 * (k + 1) + seed for k, src in enumerate(sources)}
    descs = {src: [(base[src] + j, int(rng.integers(20, 60)))
                   for j in range(int(rng.integers(2, 4)))]
             for src in sources}

    inc = _session(os.path.join(tmp_path, "inc"))
    for step, delta in enumerate(("initial",) + tuple(deltas)):
        if delta == "append":
            src = str(rng.choice(sources))
            descs[src] = descs[src] + [
                (base[src] + 100 + step, int(rng.integers(20, 60)))]
        elif delta == "full-change":
            for src in sources:
                descs[src] = [(s + 10_000, n) for s, n in descs[src]]

        wf = build_workflow(specs, descs)
        dag = wf.build()
        sigs = compute_signatures(dag)
        plans = compute_chunk_signatures(dag, sigs)
        missing = {n: sum(1 for cs in p.chunk_sigs
                          if not inc.store.has_local(cs))
                   for n, p in plans.items()}

        rep = inc.run(build_workflow(specs, descs))
        cold = _session(os.path.join(tmp_path, f"cold{step}"))
        cold_rep = cold.run(build_workflow(specs, descs))

        assert rep.outputs.keys() == cold_rep.outputs.keys()
        for out in rep.outputs:
            _assert_bit_identical(rep.outputs[out], cold_rep.outputs[out],
                                  f"seed={seed} step={step}({delta}) "
                                  f"output={out}")
        for n, p in plans.items():
            if p.mode == "union":
                continue  # concat never invokes fn
            got = rep.execution.chunk_computed.get(n, 0)
            assert got == missing[n], (
                f"seed={seed} step={step}({delta}) node={n}: "
                f"{got} chunks computed, {missing[n]} were missing")
        assert StorageLedger(inc.store.ledger_path).used() == \
            pytest.approx(float(inc.store.total_bytes())), \
            f"seed={seed} step={step}({delta}): ledger != disk"


# ---------------------------------------------------------------------------
# seeded plain-numpy driver (runs everywhere, no hypothesis needed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_oracle_seeded(tmp_path, seed):
    run_oracle(str(tmp_path), seed)


def test_append_splices_exactly_the_delta(tmp_path):
    """Deterministic map chain: an append recomputes exactly the appended
    chunk at every chunked node and reuses every prefix chunk."""
    def build(descs):
        wf = Workflow("chain")
        src = wf.source("src", lambda d=list(descs):
                        [_chunk_value(x) for x in d], chunks=list(descs))
        m1 = wf.extractor("m1", lambda x: 2.0 * x + 1.0, [src],
                          config="m1", incremental="map")
        m2 = wf.extractor("m2", lambda x: np.sin(x), [m1],
                          config="m2", incremental="map")
        red = wf.reducer("red", lambda x: np.sum(x, axis=0), [m2],
                         config="red", incremental="assoc_reduce")
        wf.output(m2)
        wf.output(red)
        return wf

    sess = _session(str(tmp_path))
    d0 = [(10, 40), (11, 40), (12, 40)]
    r0 = sess.run(build(d0))
    assert r0.execution.chunk_computed == {"src": 3, "m1": 3, "m2": 3,
                                           "red": 3}
    r1 = sess.run(build(d0 + [(13, 40)]))
    assert r1.execution.chunk_computed == {"src": 1, "m1": 1, "m2": 1,
                                           "red": 1}
    assert r1.execution.chunk_reused == {"src": 3, "m1": 3, "m2": 3,
                                         "red": 3}
    cold = _session(os.path.join(str(tmp_path), "cold"))
    rc = cold.run(build(d0 + [(13, 40)]))
    for out in ("m2", "red"):
        _assert_bit_identical(r1.outputs[out], rc.outputs[out], out)


def test_full_change_recomputes_everything(tmp_path):
    def build(descs):
        wf = Workflow("chain")
        src = wf.source("src", lambda d=list(descs):
                        [_chunk_value(x) for x in d], chunks=list(descs))
        m1 = wf.extractor("m1", lambda x: x * x, [src],
                          config="m1", incremental="map")
        wf.output(m1)
        return wf

    sess = _session(str(tmp_path))
    sess.run(build([(1, 30), (2, 30)]))
    r = sess.run(build([(7, 30), (8, 30)]))   # every chunk id changed
    assert r.execution.chunk_computed == {"src": 2, "m1": 2}
    assert r.execution.chunk_reused == {"src": 0, "m1": 0}


def test_opaque_node_breaks_the_chunk_chain(tmp_path):
    """An opaque (global-state) operator mid-chain falls back to whole
    recompute — and a map downstream of it gets no plan either (its
    parent is not chunked), yet results stay bit-identical."""
    def build(descs):
        wf = Workflow("mixed")
        src = wf.source("src", lambda d=list(descs):
                        [_chunk_value(x) for x in d], chunks=list(descs))
        m1 = wf.extractor("m1", lambda x: x + 1.0, [src],
                          config="m1", incremental="map")
        stz = wf.extractor("stz", lambda x: (x - x.mean()) / (x.std()
                                                              + 1e-9),
                           [m1], config="stz")   # opaque: global state
        m2 = wf.extractor("m2", lambda x: x * 3.0, [stz],
                          config="m2", incremental="map")
        wf.output(m2)
        return wf

    d = [(3, 25), (4, 25)]
    sess = _session(str(tmp_path))
    sess.run(build(d))
    d2 = d + [(5, 25)]
    r = sess.run(build(d2))
    # m1 splices; stz and m2 are whole-value (no plan).
    assert r.execution.chunk_computed.get("m1") == 1
    assert "stz" not in r.execution.chunk_computed
    assert "m2" not in r.execution.chunk_computed
    cold = _session(os.path.join(str(tmp_path), "cold"))
    rc = cold.run(build(d2))
    _assert_bit_identical(r.outputs["m2"], rc.outputs["m2"], "m2")


def test_union_concatenates_parent_manifests(tmp_path):
    def build(da, db):
        wf = Workflow("u")
        a = wf.source("a", lambda d=list(da): [_chunk_value(x) for x in d],
                      chunks=list(da))
        b = wf.source("b", lambda d=list(db): [_chunk_value(x) for x in d],
                      chunks=list(db))
        u = wf.extractor("u", lambda *vs: np.concatenate(vs, axis=0),
                         [a, b], config="u", incremental="union")
        m = wf.extractor("m", lambda x: x - 1.0, [u],
                         config="m", incremental="map")
        wf.output(m)
        return wf

    da, db = [(1, 10), (2, 10)], [(9, 15)]
    sess = _session(str(tmp_path))
    r0 = sess.run(build(da, db))
    assert r0.execution.chunk_computed["m"] == 3   # 2 + 1 chunks
    r1 = sess.run(build(da, db + [(10, 15)]))      # append to b only
    assert r1.execution.chunk_computed["m"] == 1
    assert r1.execution.chunk_reused["m"] == 3
    cold = _session(os.path.join(str(tmp_path), "cold"))
    rc = cold.run(build(da, db + [(10, 15)]))
    _assert_bit_identical(r1.outputs["m"], rc.outputs["m"], "m")


# ---------------------------------------------------------------------------
# hypothesis-driven deep variant (dev/CI only; profile ci-deep in nightly)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(seed=st.integers(0, 2 ** 16),
           n_ops=st.integers(3, 9),
           deltas=st.lists(st.sampled_from(["append", "full-change"]),
                           min_size=1, max_size=3))
    def test_differential_oracle_hypothesis(tmp_path_factory, seed, n_ops,
                                            deltas):
        tmp = tmp_path_factory.mktemp(f"oracle-{seed}-{n_ops}")
        run_oracle(str(tmp), seed, n_ops=n_ops, deltas=tuple(deltas))
