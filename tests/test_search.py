"""Reuse-aware search driver + the ISSUE 7 config/client API redesign.

Covers the acceptance surface: under an arm budget the reuse-aware
frontier computes strictly fewer nodes than a FIFO frontier on a
shared-prefix grid (and never touches the family the budget cannot
afford); successive halving kills losers with their pins/reservations
released (ledger == disk after every rung, zero live leases after the
run, zero wasted recomputes); eager (ASHA) promotion cancels stragglers
mid-run; the estimate RPC prices marginal compute against the live
store; seeded searches replay bit-identically; ``connect()`` unifies the
client constructions; and the legacy-kwarg deprecation shim resolves to
exactly the config-dataclass construction, warning once per kwarg.
"""
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (EngineConfig, IterativeSession, ResilienceConfig,
                        StoreConfig, Workflow, random_search,
                        reset_legacy_warnings)
from repro.core.locking import HAVE_FLOCK, StorageLedger
from repro.core.search import (HalvingConfig, SearchConfig, SearchDriver,
                               tune)
from repro.serve import (Client, InProcessClient, ServerClient,
                         SessionServer, connect)

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


class Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self.counts.get(name, 0)


def build_family(family: str, reg: float, calls: Calls | None = None,
                 work: int = 600) -> Workflow:
    """src → feat (slow, shared within a family) → model(reg) → eval."""
    def count(name):
        if calls is not None:
            calls.hit(name)

    wf = Workflow(f"{family}-{reg}")
    src = wf.source(
        "src",
        lambda: np.arange(4096, dtype=np.float64).reshape(64, 64),
        config=("v1", family))

    def featurize(m):
        count(f"feat_{family}")
        acc = m.copy()
        for _ in range(work):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config=("feat", family))
    model = wf.learner(
        "model", lambda z, r=reg: float(np.sum(z * z)) * r,
        [feat], config=("LR", reg))
    out = wf.reducer("eval", lambda m: {"score": m}, [model],
                     config=("eval",))
    wf.output(out)
    return wf


def build_train(lr: float, train_iters: int = 1,
                calls: Calls | None = None,
                slow_lr: float | None = None,
                stall: threading.Event | None = None) -> Workflow:
    """src → feat (shared) → train(lr, iters) → eval{score}.

    The metric rewards larger ``lr``; ``train_iters`` is the halving
    resource; an arm with ``lr == slow_lr`` is the ASHA straggler: it
    blocks on ``stall`` until the test releases it, so "slow" is a
    synchronized condition, not a wall-clock guess that races the fast
    arms on a loaded machine.
    """
    wf = Workflow(f"train-{lr}-{train_iters}")

    def load():
        # A realistic dataset load is expensive enough that OMP
        # materializes it; a free source would be recomputed by every
        # concurrently-started arm (correct economics, but it would
        # muddy the zero-wasted-recomputes accounting below).
        m = np.arange(4096, dtype=np.float64).reshape(64, 64)
        for _ in range(60):
            m = m + np.tanh(m) * 1e-3
        return m

    src = wf.source("src", load, config=("v1",))

    def featurize(m):
        # Heavy enough that OMP materializes it on cost grounds alone:
        # siblings submitted at *different* times (no live multiplicity)
        # must still find it loadable, or they recompute it blindly.
        if calls is not None:
            calls.hit("feat")
        acc = m.copy()
        for _ in range(300):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config=("feat",))

    def train(z, lr=lr, iters=train_iters):
        if slow_lr is not None and lr == slow_lr and stall is not None:
            # The timeout is a deadlock bound, not pacing: the test
            # sets the event as soon as the causal condition (the
            # driver requested this arm's cancellation) holds.
            stall.wait(timeout=60.0)
        return float(np.sum(z * z)) * lr * (1.0 + 0.01 * iters)

    model = wf.learner("train", train, [feat],
                       config=("sgd", lr, train_iters))
    out = wf.reducer("eval", lambda m: {"score": m}, [model],
                     config=("eval",))
    wf.output(out)
    return wf


# ---------------------------------------------------------------------------
# the acceptance bar: reuse-aware ordering beats FIFO under a budget
# ---------------------------------------------------------------------------
def test_reuse_frontier_computes_fewer_nodes_than_fifo(tmp_path):
    """Interleaved two-family grid, budget of 3 arms, one slot: FIFO
    spends the budget across both families (two heavy prefixes); the
    reuse frontier, re-estimating marginal cost against the live store
    at every pick, stays signature-adjacent and never touches the second
    family. Strictly fewer node computes at the same arm count."""
    space = [{"family": f, "reg": r}
             for r in (0.1, 0.2, 0.4) for f in ("a", "b")]

    def run(frontier, workdir):
        calls = Calls()
        registry = {"fam": lambda family, reg:
                    build_family(family, reg, calls)}
        server = SessionServer(str(workdir), registry=registry,
                               engine=EngineConfig(n_sessions=1),
                               poll_interval=0.01)
        try:
            driver = SearchDriver(
                server, "fam", space=space,
                config=SearchConfig(strategy="grid", max_arms=3,
                                    frontier=frontier, max_inflight=1))
            report = driver.run()
        finally:
            server.shutdown()
        return report, calls

    reuse, reuse_calls = run("reuse", tmp_path / "reuse")
    fifo, fifo_calls = run("fifo", tmp_path / "fifo")

    for rep in (reuse, fifo):
        done = [a for a in rep.arms if a.status == "done"]
        skipped = [a for a in rep.arms if a.status == "skipped"]
        assert len(done) == 3 and len(skipped) == 3
        assert rep.wasted_recomputes() == 0

    # FIFO's first 3 arms touch both families; reuse-aware stays in one.
    assert fifo_calls.get("feat_a") == 1 and fifo_calls.get("feat_b") == 1
    assert sorted([reuse_calls.get("feat_a"),
                   reuse_calls.get("feat_b")]) == [0, 1]
    assert reuse.total_node_computes() < fifo.total_node_computes()
    # The frontier recorded why: later picks had hits, hence a smaller
    # marginal than their total.
    priced = [a.estimate for a in reuse.arms if a.estimate is not None]
    assert any(e["n_hit"] > 0 and e["marginal_s"] < e["total_s"]
               for e in priced)


def test_estimate_rpc_prices_against_live_store(tmp_path):
    """Cold store: marginal == total. After one arm runs, a sibling's
    estimate sees store hits and a strictly smaller marginal; a disjoint
    family still prices at full cost."""
    registry = {"fam": lambda family, reg: build_family(family, reg)}
    server = SessionServer(str(tmp_path), registry=registry,
                           engine=EngineConfig(n_sessions=1),
                           poll_interval=0.01)
    try:
        client = connect(server)
        cold = client.estimate("fam", {"family": "a", "reg": 0.1})
        assert cold["n_hit"] == 0
        assert cold["marginal_s"] == pytest.approx(cold["total_s"])
        job = client.submit("fam", {"family": "a", "reg": 0.1})
        assert client.wait(job)["status"] == "done"
        warm = client.estimate("fam", {"family": "a", "reg": 0.2})
        assert warm["n_hit"] >= 1
        assert warm["marginal_s"] < warm["total_s"]
        other = client.estimate("fam", {"family": "b", "reg": 0.2})
        assert other["n_hit"] == 0
        assert other["marginal_s"] == pytest.approx(other["total_s"])
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# successive halving: losers die clean
# ---------------------------------------------------------------------------
def test_halving_promotes_top_and_releases_ledger(tmp_path):
    """3 arms over 2 rungs at eta=2: rung 0 runs all at the low resource
    level, the top 2 promote to the high level, the loser never does.
    After *every* rung the shared ledger equals on-disk bytes (no
    reservation leaked by a loser), and the run ends with zero live
    leases and zero wasted recomputes."""
    registry = {"train": lambda lr, train_iters:
                build_train(lr, train_iters)}
    server = SessionServer(str(tmp_path), registry=registry,
                           engine=EngineConfig(n_sessions=3),
                           poll_interval=0.01)
    drift_checks: list[tuple[float, int]] = []

    def on_rung(summary):
        ledger = StorageLedger(server.store.ledger_path)
        drift_checks.append((ledger.used(), server.store.total_bytes()))

    try:
        driver = SearchDriver(
            server, "train",
            space=[{"lr": lr} for lr in (0.1, 0.2, 0.3)],
            config=SearchConfig(
                strategy="grid", metric="eval.score", max_inflight=3,
                halving=HalvingConfig(resource="train_iters",
                                      levels=[1, 3], eta=2.0),
                on_rung=on_rung))
        report = driver.run()
    finally:
        server.shutdown()

    assert len(report.rungs) == 2
    assert drift_checks and all(used == disk
                                for used, disk in drift_checks)
    r0, r1 = report.rungs
    assert r0["n_done"] == 3 and len(r0["promoted"]) == 2
    assert r1["n_done"] == 2
    # the metric rewards lr: 0.2 and 0.3 promote, 0.1 never reaches rung 1
    rung1 = [a for a in report.arms if a.rung == 1]
    assert sorted(a.base_params["lr"] for a in rung1) == [0.2, 0.3]
    assert all(a.params["train_iters"] == 3 for a in rung1)
    best = report.best()
    assert best.rung == 1 and best.base_params["lr"] == 0.3
    assert report.wasted_recomputes() == 0
    counts = server.store.lease_counts()
    assert counts["compute"] == 0 and counts["pins"] == 0
    ledger = StorageLedger(server.store.ledger_path)
    assert ledger.used() == server.store.total_bytes()


def test_eager_halving_cancels_straggler(tmp_path):
    """ASHA mode: with one deliberately slow arm, the first two finishers
    fill the promotion quota and the straggler is cancelled mid-run — it
    never reaches rung 1, and its pins/reservations are settled (zero
    live leases, ledger == disk)."""
    calls = Calls()
    stall = threading.Event()
    registry = {"train": lambda lr, train_iters:
                build_train(lr, train_iters, calls=calls, slow_lr=99.0,
                            stall=stall)}
    server = SessionServer(str(tmp_path), registry=registry,
                           engine=EngineConfig(n_sessions=3),
                           poll_interval=0.01)

    def release_on_cancel():
        # Event-synchronized straggler release: unblock the slow arm
        # only once the driver has *requested* its cancellation — the
        # causal condition the old fixed sleep merely guessed at — so
        # the straggler can never finish rung 0 first, at any machine
        # speed. The deadline is a deadlock bound for the failure case.
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline and not stall.is_set():
            with server._cv:
                requested = any(j.cancel_event.is_set()
                                for j in server._jobs.values())
            if requested:
                break
            time.sleep(0.01)
        stall.set()

    watcher = threading.Thread(target=release_on_cancel, daemon=True)
    watcher.start()
    try:
        driver = SearchDriver(
            server, "train",
            space=[{"lr": 99.0}, {"lr": 0.2}, {"lr": 0.3}],
            config=SearchConfig(
                strategy="grid", metric="eval.score", max_inflight=3,
                frontier="fifo",     # submit all three immediately
                halving=HalvingConfig(resource="train_iters",
                                      levels=[1, 3], eta=1.5,
                                      eager=True)))
        report = driver.run()
    finally:
        stall.set()
        watcher.join(timeout=5.0)
        server.shutdown()

    assert report.n_cancelled() == 1
    cancelled = [a for a in report.arms if a.status == "cancelled"]
    assert cancelled[0].base_params["lr"] == 99.0
    rung1 = [a for a in report.arms if a.rung == 1]
    assert sorted(a.base_params["lr"] for a in rung1) == [0.2, 0.3]
    assert all(a.status == "done" for a in rung1)
    counts = server.store.lease_counts()
    assert counts["compute"] == 0 and counts["pins"] == 0
    ledger = StorageLedger(server.store.ledger_path)
    assert ledger.used() == server.store.total_bytes()


# ---------------------------------------------------------------------------
# seeded reproducibility
# ---------------------------------------------------------------------------
def test_random_strategy_replays_bit_identically(tmp_path):
    """Same seed → the same draw sequence (and the report records it);
    a different seed draws a different sequence."""
    axes = {"lr": [0.1, 0.2, 0.3, 0.4, 0.5],
            "train_iters": [1, 2, 3, 4]}
    registry = {"train": lambda lr, train_iters:
                build_train(lr, train_iters)}

    def run(workdir, seed):
        server = SessionServer(str(workdir), registry=registry,
                               engine=EngineConfig(n_sessions=2),
                               poll_interval=0.01)
        try:
            driver = SearchDriver(
                server, "train", axes=axes,
                config=SearchConfig(strategy="random", max_arms=4,
                                    frontier="fifo", seed=seed,
                                    detail=False))
            return driver.run()
        finally:
            server.shutdown()

    a = run(tmp_path / "a", seed=7)
    b = run(tmp_path / "b", seed=7)
    c = run(tmp_path / "c", seed=8)
    assert a.seed == b.seed == 7
    assert [x.params for x in a.arms] == [x.params for x in b.arms]
    assert [x.params for x in a.arms] != [x.params for x in c.arms]
    assert all(x.status == "done" for x in a.arms)


def test_random_search_seed_recorded_and_reproducible():
    """The sweep helper's new seed= draws the same variants twice and
    stamps the seed on each variant for replay from a report."""
    def mutate(knobs, rng):
        return {"lr": float(rng.uniform(0.0, 1.0))}

    build = lambda kn: build_train(kn["lr"])  # noqa: E731
    v1 = random_search({"lr": 0.5}, mutate, 4, build=build, seed=13)
    v2 = random_search({"lr": 0.5}, mutate, 4, build=build, seed=13)
    v3 = random_search({"lr": 0.5}, mutate, 4, build=build, seed=14)
    assert [v.knobs for v in v1] == [v.knobs for v in v2]
    assert [v.knobs for v in v1] != [v.knobs for v in v3]
    assert all(v.seed == 13 for v in v1)
    with pytest.raises(TypeError):
        random_search({"lr": 0.5}, mutate, 4)   # build is required


# ---------------------------------------------------------------------------
# mutation (beam) search
# ---------------------------------------------------------------------------
def test_mutation_search_climbs_the_metric(tmp_path):
    """Greedy beam search: each round keeps the best arms and expands
    seeded mutations; the best metric never gets worse round over round
    and dedupe never resubmits a visited point."""
    registry = {"train": lambda lr: build_train(lr)}

    def mutate(params, rng):
        step = float(rng.choice([-0.05, 0.05, 0.1]))
        return {"lr": round(min(1.0, max(0.0, params["lr"] + step)), 3)}

    report = tune(str(tmp_path), registry, "train",
                  base={"lr": 0.2}, mutate=mutate,
                  config=SearchConfig(strategy="mutate",
                                      metric="eval.score",
                                      beam_width=1, children=2,
                                      rounds=3, max_inflight=2,
                                      seed=3))
    assert report.strategy == "mutate"
    assert len(report.rungs) >= 2
    done = [a for a in report.arms if a.status == "done"]
    assert len(done) == len(report.arms)
    # dedupe: no parameter point is ever submitted twice across rounds
    assert len({tuple(sorted(a.base_params.items()))
                for a in report.arms}) == len(report.arms)
    # each round expands at most beam_width * children mutations, all
    # derived from that round's single beam survivor
    for r in report.rungs:
        assert len(r["promoted"]) <= 1
        if r["rung"] > 0:
            assert r["n_arms"] <= 1 * 2
    # the winner is an arm the search actually visited and ranked
    best = report.best()
    assert best is not None
    assert best.metric == max(a.metric for a in done)


# ---------------------------------------------------------------------------
# connect(): one front door for every client shape
# ---------------------------------------------------------------------------
def test_connect_unifies_client_construction(tmp_path):
    registry = {"fam": lambda reg=0.1: build_family("a", reg)}
    server = SessionServer(str(tmp_path / "srv"), registry=registry,
                           engine=EngineConfig(n_sessions=1),
                           poll_interval=0.01)
    sock = server.serve_unix(str(tmp_path / "helix.sock"))
    host, port = server.serve_tcp("127.0.0.1", 0)
    try:
        inproc = connect(server)
        assert isinstance(inproc, InProcessClient)
        assert isinstance(inproc, Client)          # runtime protocol
        assert connect(inproc) is inproc           # idempotent
        with connect(sock) as over_unix:
            assert isinstance(over_unix, ServerClient)
            assert over_unix.hello()["workflows"] == ["fam"]
        with connect(f"{host}:{port}") as over_tcp:
            assert isinstance(over_tcp, ServerClient)
            job = over_tcp.submit("fam", {"reg": 0.2})
            assert over_tcp.wait(job)["status"] == "done"
        with pytest.raises(TypeError):
            connect(12345)
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# config API: shim equivalence + warn-once
# ---------------------------------------------------------------------------
def test_legacy_kwargs_resolve_to_config_and_warn_once(tmp_path):
    """A legacy-kwarg construction resolves to the exact same config
    dataclasses as the config-API construction; each deprecated kwarg
    warns once per process, then never again."""
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = IterativeSession(
            str(tmp_path / "legacy"), max_workers=2, prefetch_depth=8,
            storage_budget_bytes=1e9, dedupe_wait_seconds=5.0)
    deps = [w for w in caught if issubclass(w.category,
                                            DeprecationWarning)]
    assert len(deps) == 4            # one per legacy kwarg
    assert any("EngineConfig" in str(w.message) for w in deps)
    assert any("StoreConfig" in str(w.message) for w in deps)
    assert any("ResilienceConfig" in str(w.message) for w in deps)

    with warnings.catch_warnings(record=True) as again:
        warnings.simplefilter("always")
        legacy2 = IterativeSession(
            str(tmp_path / "legacy2"), max_workers=2, prefetch_depth=8,
            storage_budget_bytes=1e9, dedupe_wait_seconds=5.0)
    assert not [w for w in again if issubclass(w.category,
                                               DeprecationWarning)]

    with warnings.catch_warnings(record=True) as clean:
        warnings.simplefilter("always")
        modern = IterativeSession(
            str(tmp_path / "modern"),
            engine=EngineConfig(max_workers=2, prefetch_depth=8),
            storage=StoreConfig(budget_bytes=1e9),
            resilience=ResilienceConfig(dedupe_wait_seconds=5.0))
    assert not [w for w in clean if issubclass(w.category,
                                               DeprecationWarning)]

    for a in (legacy, legacy2):
        assert a.engine_config == modern.engine_config
        assert a.store_config == modern.store_config
        assert a.resilience_config == modern.resilience_config
    # resolved call-site defaults are explicit in the frozen configs
    assert modern.engine_config.share_nondet is False
    assert modern.store_config.purge_stale is True


def test_server_config_equivalence(tmp_path):
    """Same shim contract on the server, whose call-site defaults differ
    from a standalone session's (fleet sharing on by default)."""
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = SessionServer(str(tmp_path / "legacy"), n_sessions=2,
                               schedule="fifo", poll_interval=0.01)
    assert [w for w in caught
            if issubclass(w.category, DeprecationWarning)]
    modern = SessionServer(str(tmp_path / "modern"),
                           engine=EngineConfig(n_sessions=2,
                                               schedule="fifo"),
                           poll_interval=0.01)
    try:
        assert legacy.engine_config == modern.engine_config
        assert legacy.store_config == modern.store_config
        assert legacy.resilience_config == modern.resilience_config
        assert modern.engine_config.share_nondet is True
        assert modern.store_config.purge_stale is False
    finally:
        legacy.shutdown()
        modern.shutdown()


def test_config_type_errors_are_loud(tmp_path):
    with pytest.raises(TypeError, match="EngineConfig"):
        IterativeSession(str(tmp_path), engine=StoreConfig())
