"""End-to-end behavior of the full system: the census workflow (the paper's
running example) through IterativeSession under all three policies, plus
fault-tolerant training-segment reuse (Helix-JAX's checkpoint/restart)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import workflows as W
from repro import configs
from repro.core import IterativeSession, Policy, Workflow
from repro.data import synth
from repro.data.pipeline import TokenBatcher
from repro.train import steps


@pytest.fixture(scope="module")
def small_census():
    return dataclasses.replace(W.CensusKnobs(), n_rows=4000)


def test_census_end_to_end_all_policies(tmp_path, small_census):
    outs = {}
    for policy in (Policy.OPT, Policy.ALWAYS, Policy.NEVER):
        sess = IterativeSession(str(tmp_path / policy.value), policy=policy)
        r0 = sess.run(W.build_census(small_census))
        # PPR edit: only the reducer changes
        k1 = dataclasses.replace(small_census, eval_metric="f1")
        r1 = sess.run(W.build_census(k1))
        outs[policy] = (r0.outputs["checkResults"]["value"],
                        r1.outputs["checkResults"]["value"])
        # census raceExt must be sliced away (paper Fig. 3)
        assert "raceExt" in r0.sliced_away
        if policy is Policy.OPT:
            # PPR iteration: the expensive learner must not retrain
            assert "incPred" not in r1.original
            states = r1.execution.states
            assert states["incPred"].value in ("prune", "load")
    # identical numbers under every policy (Theorem 1)
    vals = list(outs.values())
    assert all(v == vals[0] for v in vals)
    # the model actually learned something
    assert vals[0][0] > 0.6


def test_census_model_quality(small_census):
    """The LR learner must beat the majority-class baseline."""
    wf = W.build_census(small_census)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        rep = IterativeSession(d).run(wf)
    acc = rep.outputs["checkResults"]["value"]
    rows = synth.census_rows(7, small_census.n_rows)
    majority = max(np.mean(rows["target"]), 1 - np.mean(rows["target"]))
    assert acc > majority + 0.02


def test_training_segments_resume_after_crash(tmp_path):
    """Train a tiny LM as 3 Helix segment nodes; 'crash' after segment 2 and
    restart: the new session must LOAD segments 1-2 and compute only 3."""
    cfg = configs.reduced(configs.get("internlm2-1.8b"))
    tokens = synth.lm_tokens(0, 30_000, cfg.vocab_size)
    batcher = TokenBatcher(tokens, batch=4, seq=32)
    jstep = jax.jit(lambda st, b: steps.train_step(
        cfg, st, b, peak_lr=1e-3, warmup_steps=2, total_steps=100))

    def make_wf(n_segments):
        wf = Workflow("train-lm")
        prev = wf.source(
            "init", lambda: steps.init_train_state(cfg, jax.random.PRNGKey(0)),
            config="init-v1")
        for s in range(n_segments):
            def seg_fn(state, _s=s):
                for i in range(_s * 3, (_s + 1) * 3):
                    state, _ = jstep(state, {
                        k: jnp.asarray(v)
                        for k, v in batcher.batch_at(i).items()})
                return state
            prev = wf.segment(f"seg{s}", seg_fn, [prev], config=("seg", s, 3))
        out = wf.reducer("final_step", lambda st: float(st.opt.step),
                         [prev], config="v1")
        wf.output(out)
        return wf

    # run 1: only two segments "completed" before the crash
    s1 = IterativeSession(str(tmp_path))
    r1 = s1.run(make_wf(2))
    assert r1.outputs["final_step"] == 6.0
    # run 2 (restart with the full plan): segments 0-1 reused
    s2 = IterativeSession(str(tmp_path))
    r2 = s2.run(make_wf(3))
    states = r2.execution.states
    assert states["seg0"].value in ("load", "prune")
    assert states["seg1"].value == "load"
    assert states["seg2"].value == "compute"
    assert r2.outputs["final_step"] == 9.0


def test_nondeterministic_workflow_not_reused(tmp_path):
    knobs = dataclasses.replace(W.MNISTKnobs(), n_images=800, epochs=5,
                                n_features=64)
    sess = IterativeSession(str(tmp_path))
    sess.run(W.build_mnist(knobs))
    r1 = sess.run(W.build_mnist(knobs))   # identical knobs…
    # …but randomFFT is nondeterministic → it and descendants recompute
    assert r1.execution.states["randomFFT"].value == "compute"
    assert r1.execution.states["softmax"].value == "compute"
    assert "randomFFT" in r1.original and "mnist" not in r1.original
