"""End-to-end session behavior: Theorem 1 correctness, reuse, purge."""
import numpy as np
import pytest

from repro.core import IterativeSession, Policy, Workflow

CALLS = {"parse": 0, "feat": 0, "model": 0}


def make_wf(reg=0.1, nfeat=2, bug=False):
    wf = Workflow("toy")
    src = wf.source("src", lambda: np.arange(400_000, dtype=np.float64),
                    config="v1")

    def parse(x):
        CALLS["parse"] += 1
        out = x % 9973
        for _ in range(8):                 # deliberately expensive: loading
            out = np.sort(out)[::-1].copy()  # beats recomputing (paper §5.1)
        return np.sort(out)

    def feat(x):
        CALLS["feat"] += 1
        return np.stack([x ** i for i in range(1, nfeat + 1)])

    def model(f):
        CALLS["model"] += 1
        return f.mean(axis=1) * (reg if not bug else -reg)

    p = wf.scanner("parse", parse, [src], config="v1")
    f = wf.extractor("feat", feat, [p], config=nfeat)
    m = wf.learner("model", model, [f], config=reg)
    e = wf.reducer("eval", lambda mm: float(np.sum(mm)), [m], config="v1")
    wf.output(e)
    return wf


def fresh_output(**kw):
    """Ground truth: run the workflow functions directly."""
    x = np.arange(400_000, dtype=np.float64)
    x = np.sort(x % 9973)
    nfeat = kw.get("nfeat", 2)
    f = np.stack([x ** i for i in range(1, nfeat + 1)])
    m = f.mean(axis=1) * kw.get("reg", 0.1)
    return float(np.sum(m))


def test_theorem1_correctness_across_changes(tmp_path):
    sess = IterativeSession(str(tmp_path))
    r0 = sess.run(make_wf())
    assert r0.outputs["eval"] == pytest.approx(fresh_output())
    # PPR-free re-run: pure reuse, same answer
    r1 = sess.run(make_wf())
    assert r1.outputs["eval"] == pytest.approx(fresh_output())
    assert r1.execution.n_computed == 0
    # L/I change: model+eval recompute; upstream reused/pruned
    r2 = sess.run(make_wf(reg=0.5))
    assert r2.outputs["eval"] == pytest.approx(fresh_output(reg=0.5))
    assert "model" in r2.original and "eval" in r2.original
    assert "parse" not in r2.original
    # DPR change: everything below feat recomputes
    r3 = sess.run(make_wf(nfeat=3))
    assert r3.outputs["eval"] == pytest.approx(fresh_output(nfeat=3))


def test_reuse_avoids_recomputation(tmp_path):
    CALLS.update(parse=0, feat=0, model=0)
    sess = IterativeSession(str(tmp_path))
    sess.run(make_wf())
    n_parse = CALLS["parse"]
    sess.run(make_wf(reg=0.9))     # only model/eval changed
    assert CALLS["parse"] == n_parse, "parse recomputed despite equivalence"


def test_restart_resumes_from_store(tmp_path):
    """A new session (process restart) reuses the previous session's
    materializations — the checkpoint/restart story."""
    s1 = IterativeSession(str(tmp_path))
    s1.run(make_wf())
    CALLS.update(parse=0, feat=0, model=0)
    s2 = IterativeSession(str(tmp_path))    # fresh process, same workdir
    r = s2.run(make_wf())
    assert CALLS["parse"] == 0 and CALLS["model"] == 0
    assert r.execution.n_computed == 0
    assert r.outputs["eval"] == pytest.approx(fresh_output())


def test_purge_on_change(tmp_path):
    sess = IterativeSession(str(tmp_path))
    sess.run(make_wf(reg=0.1))
    r = sess.run(make_wf(reg=0.7))
    # stale 'model'/'eval' materializations purged
    assert r.purged_bytes > 0
    names_now = [m["name"] for m in sess.store.entries().values()]
    assert names_now.count("eval") <= 1


def test_unused_nodes_sliced(tmp_path):
    wf = make_wf()
    wf.extractor("dangling", lambda x: x + 1, ["parse"], config="v")
    sess = IterativeSession(str(tmp_path))
    rep = sess.run(wf)
    assert "dangling" in rep.sliced_away


def test_policies_same_outputs(tmp_path):
    outs = {}
    for policy in (Policy.OPT, Policy.ALWAYS, Policy.NEVER):
        sess = IterativeSession(str(tmp_path / policy.value), policy=policy)
        sess.run(make_wf())
        rep = sess.run(make_wf(reg=0.3))
        outs[policy] = rep.outputs["eval"]
    assert len(set(round(v, 9) for v in outs.values())) == 1
