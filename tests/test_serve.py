"""Session server: many clients, one store, globally-aware scheduling.

Covers the ISSUE 3 acceptance surface: shared-prefix-first dispatch order
under staggered arrival (vs. the FIFO baseline), sibling deferral in favor
of independent work, N concurrent in-process clients bit-identical to
isolated runs, shared worker-pool fairness, graceful drain on shutdown,
and the unix/TCP JSON protocol round-trip.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import IterativeSession, Workflow
from repro.core.locking import HAVE_FLOCK
from repro.serve import (InProcessClient, ServerError, SessionServer,
                         SharedWorkerPool, connect_tcp, connect_unix)

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


class Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self.counts.get(name, 0)


def build_family(family: str, reg: float, calls: Calls | None = None,
                 work: int = 600) -> Workflow:
    """src → feat (slow, shared within a family) → model(reg) → eval.

    Two workflows of the same ``family`` share everything up to ``feat``;
    different families are completely disjoint. ``work`` scales the
    prefix's compute cost.
    """
    def count(name):
        if calls is not None:
            calls.hit(name)

    wf = Workflow(f"{family}-{reg}")
    src = wf.source(
        "src",
        lambda: np.arange(4096, dtype=np.float64).reshape(64, 64),
        config=("v1", family))

    def featurize(m):
        count(f"feat_{family}")
        acc = m.copy()
        for _ in range(work):
            acc = np.tanh(acc @ m.T @ m / m.size)
        return acc

    feat = wf.extractor("feat", featurize, [src], config=("feat", family))
    model = wf.learner(
        "model", lambda z, r=reg: float(np.sum(z * z)) * r,
        [feat], config=("LR", reg))
    out = wf.reducer("eval", lambda m: {"score": m}, [model],
                     config=("eval",))
    wf.output(out)
    return wf


# ---------------------------------------------------------------------------
# global scheduling
# ---------------------------------------------------------------------------
def test_prefix_first_dispatch_order(tmp_path):
    """Staggered arrival: an independent job arrives first, then two
    siblings sharing an expensive prefix. Prefix-first runs a sibling
    first (its prefix is the most shared work in the system) even though
    it arrived later; FIFO preserves arrival order."""
    def run(schedule, workdir):
        calls = Calls()
        server = SessionServer(str(workdir), n_sessions=1,
                               schedule=schedule, poll_interval=0.01)
        try:
            with server.hold_dispatch():
                server.submit(lambda: build_family("b", 0.5, calls),
                              name="B")
                server.submit(lambda: build_family("a", 0.1, calls),
                              name="A1")
                server.submit(lambda: build_family("a", 0.2, calls),
                              name="A2")
            server.wait_all()
        finally:
            server.shutdown()
        return server.dispatch_log, calls

    log, calls = run("prefix", tmp_path / "prefix")
    assert log[0] == "A1"              # shared prefix scheduled first
    assert set(log) == {"A1", "A2", "B"}
    assert calls.get("feat_a") == 1    # prefix computed once fleet-wide
    assert calls.get("feat_b") == 1

    log_fifo, calls_fifo = run("fifo", tmp_path / "fifo")
    assert log_fifo == ["B", "A1", "A2"]   # arrival order
    assert calls_fifo.get("feat_a") == 1   # lease dedupe still holds


def test_sibling_deferral_prefers_independent_work(tmp_path):
    """With 2 slots and [A1, A2, B] queued (A-family shares a slow
    prefix), the global scheduler dispatches A1 + B: A2 would only block
    on A1's compute lease, so the slot goes to independent work first and
    A2 follows (reusing the prefix, never recomputing it)."""
    calls = Calls()
    server = SessionServer(str(tmp_path), n_sessions=2,
                           poll_interval=0.01)
    try:
        with server.hold_dispatch():
            server.submit(lambda: build_family("a", 0.1, calls, work=2000),
                          name="A1")
            server.submit(lambda: build_family("a", 0.2, calls, work=2000),
                          name="A2")
            server.submit(lambda: build_family("b", 0.5, calls),
                          name="B")
        jobs = server.wait_all()
    finally:
        server.shutdown()
    assert server.dispatch_log[:2] == ["A1", "B"]
    assert server.dispatch_log[2] == "A2"
    assert calls.get("feat_a") == 1
    for j in jobs:
        assert j.status == "done", j.error
    # the sibling reused the prefix (planned load or lease-follow dedupe)
    a2 = next(j for j in jobs if j.name == "A2")
    ex = a2.report.execution
    assert ex.n_loaded + len(ex.deduped) >= 1


def test_live_multiplicity_map(tmp_path):
    """The cross-client signature-multiplicity map counts live
    submissions and empties as they finish; observed reuse lands in the
    shared cost model for future amortization."""
    server = SessionServer(str(tmp_path), n_sessions=2,
                           poll_interval=0.01)
    try:
        with server.hold_dispatch():
            jobs = [server.submit(lambda r=r: build_family("a", r),
                                  name=f"A{r}") for r in (0.1, 0.2, 0.4)]
            shared = frozenset.intersection(*[j.sigs for j in jobs])
            assert shared  # the family prefix
            for sig in shared:
                assert server.multiplicity(sig) == 3
        server.wait_all(jobs)
        for sig in shared:
            assert server.multiplicity(sig) == 0
        # two siblings loaded (or dedupe-loaded) each shared value
        assert any(server.cost_model.reuse_count(s) >= 1 for s in shared)
    finally:
        server.shutdown()


# ---------------------------------------------------------------------------
# correctness: concurrent clients == isolated runs
# ---------------------------------------------------------------------------
def test_concurrent_clients_bit_identical_to_isolated(tmp_path):
    """N clients hammering one server concurrently get outputs
    bit-identical to N isolated cold runs."""
    regs = [0.1, 0.2, 0.4, 0.8]
    registry = {"fam": lambda reg: build_family("a", reg)}
    server = SessionServer(str(tmp_path / "srv"), registry=registry,
                           n_sessions=len(regs), poll_interval=0.01)
    wire_results: dict[float, dict] = {}
    errors: list[BaseException] = []

    def client_thread(reg: float) -> None:
        try:
            client = InProcessClient(server)
            job_id = client.submit("fam", {"reg": reg}, name=f"c{reg}")
            wire_results[reg] = client.wait(job_id)
        except BaseException as e:  # surfaced below
            errors.append(e)

    try:
        threads = [threading.Thread(target=client_thread, args=(r,))
                   for r in regs]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        server.shutdown()
    assert not errors
    for reg in regs:
        iso = IterativeSession(str(tmp_path / f"iso{reg}"))
        expected = iso.run(build_family("a", reg)).outputs
        assert wire_results[reg]["status"] == "done"
        # outputs here are plain floats, so the JSON wire form is exact
        assert wire_results[reg]["outputs"] == expected


# ---------------------------------------------------------------------------
# shared worker pool
# ---------------------------------------------------------------------------
def test_shared_pool_floor_and_bound():
    """Every session always gets its inline worker (progress floor);
    borrowed workers never exceed the pool size.

    Event-synchronized, not sleep-synchronized: every worker holds its
    slot until all three sessions' *inline* workers are live (the inline
    worker runs in the session's own thread, so three live inline
    workers prove all three ``run`` calls decided their width while no
    slot had been returned). The ``sum(widths)`` bound therefore cannot
    flake on a slow runner where sleeping sessions would serialize."""
    pool = SharedWorkerPool(2)
    lock = threading.Lock()
    live, peak, inline_live = [0], [0], [0]
    release = threading.Event()
    session_threads: set = set()

    def worker():
        with lock:
            live[0] += 1
            peak[0] = max(peak[0], live[0])
            if threading.current_thread() in session_threads:
                inline_live[0] += 1
        release.wait(timeout=60.0)
        with lock:
            live[0] -= 1

    widths: list[int] = []

    def one_session():
        widths.append(pool.run(worker, want=4))

    threads = [threading.Thread(target=one_session) for _ in range(3)]
    session_threads.update(threads)
    for t in threads:
        t.start()
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline:
        with lock:
            if inline_live[0] == 3:
                break
        time.sleep(0.001)
    release.set()
    for t in threads:
        t.join()
    assert inline_live[0] == 3           # progress floor held everywhere
    assert len(widths) == 3 and all(w >= 1 for w in widths)
    assert sum(widths) <= 3 + 2          # 3 inline + at most 2 borrowed
    assert peak[0] <= 3 + 2
    assert pool.peak_in_use <= 2
    assert pool.in_use == 0              # all slots returned


def test_server_sessions_share_one_pool(tmp_path):
    """3 sessions × max_workers=4 draw from one 2-slot pool: the
    process-wide borrowed-worker count stays ≤ 2."""
    server = SessionServer(str(tmp_path), n_sessions=3, pool_workers=2,
                           max_workers=4, poll_interval=0.01)
    try:
        with server.hold_dispatch():
            jobs = [server.submit(lambda f=f: build_family(f, 0.1),
                                  name=f) for f in ("x", "y", "z")]
        server.wait_all(jobs)
    finally:
        server.shutdown()
    for j in jobs:
        assert j.status == "done", j.error
    assert server.pool.peak_in_use <= 2


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------
def test_wait_timeout_is_an_error(tmp_path):
    """A wait that times out answers ok:false (client raises), never a
    partial summary a caller could mistake for a finished job."""
    server = SessionServer(str(tmp_path), n_sessions=1, poll_interval=0.01)
    try:
        client = InProcessClient(server)
        with server.hold_dispatch():   # job cannot finish while held
            job = server.submit(lambda: build_family("a", 0.1))
            with pytest.raises(ServerError, match="TimeoutError"):
                client.wait(job.id, timeout=0.05)
        server.wait(job)
        assert client.wait(job.id)["status"] == "done"
    finally:
        server.shutdown()


def test_finished_job_retention_bounded(tmp_path):
    """Only the newest max_finished_jobs reports stay resident."""
    server = SessionServer(str(tmp_path), n_sessions=1, poll_interval=0.01,
                           max_finished_jobs=2)
    try:
        jobs = []
        for i in range(4):
            jobs.append(server.submit(
                lambda i=i: build_family(f"f{i}", 0.1), name=f"f{i}"))
            server.wait(jobs[-1])
        assert jobs[0].id not in server._jobs   # evicted
        assert jobs[-1].id in server._jobs      # newest retained
    finally:
        server.shutdown()


def test_graceful_drain_on_shutdown(tmp_path):
    """drain() finishes every submitted job, then refuses new work;
    shutdown is idempotent."""
    server = SessionServer(str(tmp_path), n_sessions=1, poll_interval=0.01)
    with server.hold_dispatch():
        jobs = [server.submit(lambda f=f: build_family(f, 0.1), name=f)
                for f in ("x", "y", "z")]
    assert server.drain(timeout=120.0)
    assert all(j.status == "done" for j in jobs)
    with pytest.raises(RuntimeError):
        server.submit(lambda: build_family("late", 0.1))
    server.shutdown()
    server.shutdown()   # idempotent


def test_shutdown_without_drain_cancels_queued(tmp_path):
    """shutdown(drain=False) cancels still-queued jobs instead of running
    them; already-running work completes."""
    server = SessionServer(str(tmp_path), n_sessions=1, poll_interval=0.01)
    with server.hold_dispatch():
        jobs = [server.submit(lambda f=f: build_family(f, 0.1), name=f)
                for f in ("x", "y", "z")]
    server.shutdown(drain=False)
    statuses = {j.status for j in jobs}
    assert "cancelled" in statuses           # the tail never ran
    for j in jobs:
        assert j.done.is_set()
        assert j.status in ("done", "cancelled")


# ---------------------------------------------------------------------------
# RPC protocol
# ---------------------------------------------------------------------------
def _registry():
    return {"fam": lambda reg=0.1: build_family("a", reg)}


def test_unix_socket_protocol_roundtrip(tmp_path):
    server = SessionServer(str(tmp_path / "srv"), registry=_registry(),
                           n_sessions=2, poll_interval=0.01)
    path = server.serve_unix(str(tmp_path / "helix.sock"))
    try:
        with connect_unix(path) as client:
            hello = client.hello()
            assert hello["workflows"] == ["fam"]
            job_id = client.submit("fam", {"reg": 0.3})
            result = client.wait(job_id)
            assert result["status"] == "done"
            assert "score" in result["outputs"]["eval"]
            assert result["execution"]["n_computed"] >= 1
            status = client.status()
            assert status["total_jobs"] == 1
            # finished jobs can be released eagerly; twice is a no-op
            assert client.forget(job_id) is True
            assert client.forget(job_id) is False
            with pytest.raises(ServerError):
                client.submit("nope", {})
            with pytest.raises(ServerError):
                client.wait("no-such-job")
    finally:
        server.shutdown()


def test_tcp_protocol_roundtrip(tmp_path):
    server = SessionServer(str(tmp_path), registry=_registry(),
                           n_sessions=1, poll_interval=0.01)
    host, port = server.serve_tcp("127.0.0.1", 0)
    try:
        with connect_tcp(host, port) as client:
            job_id = client.submit("fam", {"reg": 0.2})
            result = client.wait(job_id)
            assert result["status"] == "done"
            assert client.multiplicity("deadbeef") == 0
    finally:
        server.shutdown()


def test_client_shutdown_stops_server(tmp_path):
    """A client-initiated shutdown drains and stops the server."""
    server = SessionServer(str(tmp_path), registry=_registry(),
                           n_sessions=1, poll_interval=0.01)
    path = server.serve_unix(str(tmp_path / "s.sock"))
    client = connect_unix(path)
    job_id = client.submit("fam", {})
    assert client.wait(job_id)["status"] == "done"
    assert client.shutdown()["stopping"]
    client.close()
    with server._cv:
        assert server._cv.wait_for(lambda: server._shutdown_started,
                                   timeout=30.0)
