import os
import sys

# Tests must see exactly the real local device set (1 CPU) — the 512-device
# override belongs ONLY to launch/dryrun.py.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

try:
    # Deep property-testing profile for the nightly tier-2 CI job
    # (--hypothesis-profile=ci-deep). hypothesis is a dev-only dependency
    # (requirements-dev.txt); local runs without it just use the inline
    # @settings on each test.
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci-deep", max_examples=60, deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture,
                               HealthCheck.too_slow])
except ImportError:
    pass


def make_abstract_mesh(axis_sizes, axis_names):
    """Build a ``jax.sharding.AbstractMesh`` across jax versions.

    jax <= 0.4.35 and >= 0.5 take ``(axis_sizes, axis_names)``; 0.4.36/37
    take a single ``shape_tuple`` of ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
