import os
import sys

# Tests must see exactly the real local device set (1 CPU) — the 512-device
# override belongs ONLY to launch/dryrun.py.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))
