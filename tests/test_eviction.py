"""Benefit-weighted fleet eviction (evict-to-admit) + store-ledger
accounting regressions.

Covers ISSUE 4: the evictor admits a high-benefit write by deleting the
lowest-benefit unleased entries; leased/pinned and live-multiplicity
entries are never evicted; the shared ledger equals the sum of on-disk
bytes once everything drains — including under a multiprocess
evictor-vs-reader race — and the two reservation-accounting bugs
(estimate-vs-actual drift, overwrite crediting the wrong bytes) stay
fixed.
"""
import multiprocessing as mp
import os
import time

import numpy as np
import pytest

from repro.core import (Evictor, IterativeSession, Materializer, Policy,
                        Store, Workflow, tree_nbytes)
from repro.core.dag import DAG, Node, State
from repro.core.executor import _Scheduler
from repro.core.locking import HAVE_FLOCK, StorageLedger

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")


def _fill(store: Store, sig: str, nfloats: int = 256,
          compute_s: float | None = None) -> int:
    extra = {} if compute_s is None else \
        {"compute_s": compute_s, "load_s_est": 1e-3}
    return store.save(sig, f"node-{sig}", np.ones(nfloats),
                      extra_meta=extra).nbytes


def _budget_setup(tmp_path, sigs_cost: dict[str, float | None]):
    """Store with one entry per (sig -> compute_s), ledger seeded to the
    on-disk total, and a Materializer whose budget is exactly full."""
    store = Store(str(tmp_path / "store"))
    for sig, cost in sigs_cost.items():
        _fill(store, sig, compute_s=cost)
    total = store.total_bytes()
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(float(total))
    return store, ledger, total


# -- evict-to-admit policy ----------------------------------------------------

def test_evict_to_admit_prefers_lowest_benefit(tmp_path):
    """A full budget admits a new reservation by evicting the entry with
    the lowest benefit density (no cost metadata -> stale squatter),
    never the high-C(n) one."""
    store, ledger, total = _budget_setup(
        tmp_path, {"junk": None, "good": 50.0})
    ev = Evictor(store)
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    need = store.meta("junk")["nbytes"]
    assert m.try_reserve(need)          # evicts exactly one entry
    assert store.has("good") and not store.has("junk")
    assert ev.stats.n_evicted == 1
    assert ev.stats.bytes_evicted == need
    # ledger = surviving entry + the outstanding reservation
    assert ledger.used() == store.total_bytes() + need


def test_observed_reuse_protects_entries(tmp_path):
    """Equal C(n)/l: the entry with observed loads outranks the never
    loaded one, which gets evicted first."""
    store, ledger, total = _budget_setup(
        tmp_path, {"cold": 10.0, "warm": 10.0})
    store.load("warm")                  # bump loads/last_load
    ev = Evictor(store)
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    assert m.try_reserve(store.meta("cold")["nbytes"])
    assert store.has("warm") and not store.has("cold")


def test_loaded_premetadata_entry_outranks_cheap_junk(tmp_path):
    """A pre-metadata entry (no compute_s recorded) with observed loads
    must not score zero — the (1+reuse) protection is floored at its own
    load cost, so it outranks cold junk with any tiny positive cost."""
    store = Store(str(tmp_path / "store"))
    _fill(store, "hot0")                      # no cost metadata at all
    for _ in range(3):
        store.load("hot0")
    store.save("junk", "node-junk", np.ones(256),
               extra_meta={"compute_s": 1e-4, "load_s_est": 1.0})
    total = store.total_bytes()
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(float(total))
    ev = Evictor(store)
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    assert m.try_reserve(store.meta("junk")["nbytes"])
    assert store.has("hot0") and not store.has("junk")


def test_read_lease_blocks_eviction(tmp_path):
    """A pinned (shared read lease) entry is never evicted: with every
    candidate leased, the reservation fails exactly like the old
    refuse-on-exhausted path."""
    store, ledger, total = _budget_setup(tmp_path, {"pinned": None})
    pin = store.acquire_read("pinned")
    assert pin is not None
    try:
        ev = Evictor(store)
        m = Materializer(policy=Policy.OPT,
                         storage_budget_bytes=float(total),
                         ledger=ledger, evictor=ev)
        assert not m.try_reserve(1024)
        assert store.has("pinned")
        assert ev.stats.n_evicted == 0
        assert ev.stats.n_skipped_leased >= 1
        assert ev.stats.n_unsatisfied >= 1
    finally:
        pin.release()


def test_live_multiplicity_veto(tmp_path):
    """Signatures live clients still want are never candidates even when
    their recorded benefit is lowest."""
    store, ledger, total = _budget_setup(
        tmp_path, {"wanted": None, "prized": 50.0})
    ev = Evictor(store, live_multiplicity=lambda sig: sig == "wanted")
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    # the only way to fit is to evict the *high-benefit* unprotected entry
    assert m.try_reserve(store.meta("prized")["nbytes"])
    assert store.has("wanted") and not store.has("prized")
    assert ev.stats.n_vetoed_live >= 1


def test_decide_defers_eviction_when_asked(tmp_path):
    """``evict_inline=False`` (the executor decides under its scheduler
    lock) must not run eviction I/O inside ``decide`` — the verdict
    comes back ``needs_eviction`` and the caller admits off the lock."""
    store, ledger, total = _budget_setup(tmp_path, {"junk": None})
    ev = Evictor(store)
    m = Materializer(policy=Policy.ALWAYS,
                     storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    dag, states = _chain2()
    d = m.decide(dag, "n0", states, {"n0": 5.0, "n1": 0.0}, 0.001,
                 est_bytes=1024, evict_inline=False)
    assert not d.materialize and d.needs_eviction
    assert store.has("junk") and ev.stats.n_evicted == 0   # no I/O ran
    assert m.try_reserve(1024)      # the deferred admission
    assert ev.stats.n_evicted == 1 and not store.has("junk")


def test_unsatisfiable_reservation_evicts_nothing(tmp_path):
    """A reservation that cannot fit even an empty store must not wipe
    the cache on its way to failing anyway."""
    store, ledger, total = _budget_setup(
        tmp_path, {"keep1": 10.0, "keep2": None})
    ev = Evictor(store)
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    assert not m.try_reserve(total + 1)     # larger than the whole budget
    assert store.has("keep1") and store.has("keep2")
    assert ev.stats.n_evicted == 0
    assert ev.stats.n_unsatisfied == 1


def test_incoming_density_limit_protects_better_entries(tmp_path):
    """A barely-qualifying admission must not displace strictly
    higher-benefit entries: with every candidate at or above the
    incoming write's density, nothing is evicted and the reservation
    fails (net fleet time beats admitting the worse value)."""
    store, ledger, total = _budget_setup(tmp_path, {"hot1": 50.0})
    ev = Evictor(store)
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger, evictor=ev)
    need = store.meta("hot1")["nbytes"]
    assert not m.try_reserve(need, benefit_density=1e-6)  # cold incoming
    assert store.has("hot1") and ev.stats.n_evicted == 0
    # an incoming write more valuable than the resident entry still wins
    assert m.try_reserve(need, benefit_density=float("inf"))
    assert not store.has("hot1") and ev.stats.n_evicted == 1


def test_no_evictor_keeps_refuse_on_exhausted(tmp_path):
    store, ledger, total = _budget_setup(tmp_path, {"junk": None})
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=float(total),
                     ledger=ledger)
    assert not m.try_reserve(1024)
    assert store.has("junk")


# -- ledger accounting regressions -------------------------------------------

def _exec_scheduler(store, materializer):
    dag = DAG([Node("n0", lambda: 0, is_output=True)])
    return _Scheduler(dag, {"n0": "e" * 4}, {"n0": State.COMPUTE}, store,
                      materializer, None, False, 1, 1)


def test_save_reconciles_estimate_to_actual_bytes(tmp_path):
    """Regression (ledger drift on save): the executor reserves the
    host-array estimate but disk records npy/pickle reality; the
    reservation must be reconciled to ``info.nbytes`` or the shared
    ledger drifts from ``.fleet`` truth over long sweeps."""
    store = Store(str(tmp_path / "store"))
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(0.0)
    m = Materializer(policy=Policy.ALWAYS, storage_budget_bytes=1 << 20,
                     ledger=ledger)
    sched = _exec_scheduler(store, m)
    # non-array leaf: estimated at a 64-byte nominal, pickled much larger
    value = {"arr": np.ones(16), "blob": "x" * 5000}
    est = tree_nbytes(value)
    assert m.try_reserve(est)
    info = sched._budgeted_save("e" * 4, "n0", value, est)
    assert info.nbytes != est
    assert ledger.used() == store.total_bytes() == info.nbytes
    assert m.used_bytes == info.nbytes


def test_overwrite_credits_replaced_entry_bytes(tmp_path):
    """Regression (overwrite credits the wrong bytes): replacing an entry
    frees the *old* entry's recorded bytes, not the new reservation —
    crediting ``est_bytes`` drifts the ledger whenever the sizes
    differ."""
    store = Store(str(tmp_path / "store"))
    big = np.ones(1024)
    small = np.ones(16)
    info_old = store.save("e" * 4, "n0", big)
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(float(info_old.nbytes))
    m = Materializer(policy=Policy.ALWAYS, storage_budget_bytes=1 << 20,
                     ledger=ledger)
    sched = _exec_scheduler(store, m)
    est = tree_nbytes(small)
    assert m.try_reserve(est)
    info = sched._budgeted_save("e" * 4, "n0", small, est)
    assert info.replaced and info.replaced_nbytes == info_old.nbytes
    assert ledger.used() == store.total_bytes() == info.nbytes


def test_saveinfo_reports_replaced_nbytes(tmp_path):
    store = Store(str(tmp_path / "store"))
    first = store.save("a1b2", "x", np.ones(512))
    second = store.save("a1b2", "x", np.ones(8))
    assert second.replaced
    assert second.replaced_nbytes == first.nbytes
    assert store.save("c3d4", "y", np.ones(8)).replaced_nbytes == 0


def test_overwrite_carries_load_evidence_forward(tmp_path):
    """An overwrite (same signature ⇒ same value) must not reset the
    entry's observed-reuse evidence, or the fleet's hottest entry ranks
    as cold for eviction right after two sessions race a save."""
    store = Store(str(tmp_path / "store"))
    store.save("a1b2", "x", np.ones(64))
    for _ in range(3):
        store.load("a1b2")
    before = store.meta("a1b2")
    assert before["loads"] == 3
    store.save("a1b2", "x", np.ones(64))    # the racing re-save
    after = store.meta("a1b2")
    assert after["loads"] == 3
    assert after["last_load"] == before["last_load"]


def test_drain_settles_all_pending_saves_on_error(tmp_path):
    """Regression: a failed async save must not abort the drain — the
    remaining pending saves' reservations would leak into the
    fleet-shared ledger forever (and trigger spurious evictions). Every
    entry is settled, then the first error re-raises."""
    from repro.core.store import PendingSave, SaveInfo

    store = Store(str(tmp_path / "store"))
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(0.0)
    m = Materializer(policy=Policy.NEVER, storage_budget_bytes=1 << 20,
                     ledger=ledger)
    sched = _exec_scheduler(store, m)
    # two outstanding async reservations, as the decision path leaves them
    assert m.try_reserve(100) and m.try_reserve(200)
    bad = PendingSave()
    bad._finish(None, RuntimeError("disk full"))
    good = PendingSave()
    good._finish(SaveInfo(nbytes=150, seconds=0.0))
    sched.pending_saves.extend([(100, bad), (200, good)])
    with pytest.raises(RuntimeError, match="disk full"):
        sched.run()
    # bad's 100 released; good's 200 reconciled to 150; plus whatever the
    # dag's own mandatory output persisted — ledger still equals disk.
    assert ledger.used() == store.total_bytes() + 150


def test_worker_error_still_settles_pending_saves(tmp_path):
    """Regression: a worker error must not skip the pending-save drain —
    enqueued saves' reservations would leak into the fleet ledger."""
    from repro.core.store import PendingSave, SaveInfo

    store = Store(str(tmp_path / "store"))
    ledger = StorageLedger(store.ledger_path)
    ledger.reset(0.0)
    m = Materializer(policy=Policy.NEVER, storage_budget_bytes=1 << 20,
                     ledger=ledger)
    sched = _exec_scheduler(store, m)
    assert m.try_reserve(200)
    good = PendingSave()
    good._finish(SaveInfo(nbytes=150, seconds=0.0))
    sched.pending_saves.append((200, good))
    sched.error = RuntimeError("worker boom")
    with pytest.raises(RuntimeError, match="worker boom"):
        sched.run()
    assert ledger.used() == 150        # reconciled despite the error


def test_foreign_credit_keeps_local_mirror(tmp_path):
    """Regression (stale ``used_bytes`` mirror): crediting bytes this
    instance never reserved (a §6.6 purge of a previous session's
    entries, a fleet eviction) must hit the ledger only — the local
    reserved-by-me mirror used to clamp at 0 and go inconsistent."""
    ledger = StorageLedger(str(tmp_path / "ledger.json"))
    ledger.reset(500.0)      # a previous session's entries
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=1000.0,
                     ledger=ledger)
    assert m.try_reserve(100)
    assert m.used_bytes == 100
    m.credit_foreign(500)    # purge of foreign entries
    assert m.used_bytes == 100          # my reservations unchanged
    assert ledger.used() == 100
    m.release(100)           # my own reservation undone
    assert m.used_bytes == 0 and ledger.used() == 0


def test_foreign_credit_without_ledger_hits_total_tally(tmp_path):
    """Without a ledger, ``used_bytes`` *is* the whole-store tally the
    session seeds from disk, so a foreign credit lands there."""
    m = Materializer(policy=Policy.OPT, storage_budget_bytes=1000.0)
    m.used_bytes = 800.0
    m.credit_foreign(300)
    assert m.used_bytes == 500.0


# -- OMP decision reasons -----------------------------------------------------

def _chain2():
    dag = DAG([Node("n0", None, (), is_output=False),
               Node("n1", None, ("n0",), is_output=True)])
    return dag, {"n0": State.COMPUTE, "n1": State.COMPUTE}


def test_decision_reason_reports_true_multiplier():
    """Regression (misleading OMP reasons): with an effective horizon h,
    the threshold is (1+1/h)·l — the reason must say so (and report h),
    not claim the paper's 2·l."""
    dag, states = _chain2()
    runtime = {"n0": 10.0, "n1": 0.1}
    d = Materializer(policy=Policy.OPT, horizon=4.0).decide(
        dag, "n0", states, runtime, est_load_seconds=1.0, est_bytes=8)
    assert d.materialize
    assert "1.25·l" in d.reason and "(h=4)" in d.reason
    assert d.cum_runtime == pytest.approx(10.0)
    # horizon 1 (the paper) still reads 2·l, with no h annotation
    d1 = Materializer(policy=Policy.OPT).decide(
        dag, "n0", states, runtime, est_load_seconds=1.0, est_bytes=8)
    assert "2·l" in d1.reason and "(h=" not in d1.reason


# -- end-to-end: session + sweep ----------------------------------------------

def _wf(scale: float = 1.0) -> Workflow:
    wf = Workflow("evict-e2e")
    src = wf.source("src", lambda: np.arange(4096, dtype=np.float64),
                    config="v1")

    def feat(x):
        acc = x.reshape(64, 64).copy()
        for _ in range(300):       # expensive => high C(n), worth keeping
            acc = np.tanh(acc @ acc.T / acc.size)
        return acc

    f = wf.extractor("feat", feat, [src], config="v1")
    out = wf.reducer("eval", lambda a, s=scale: float(np.sum(a)) * s, [f],
                     config=("eval", scale))
    wf.output(out)
    return wf


def test_session_evicts_junk_to_admit_high_benefit(tmp_path):
    """End-to-end: a budget squatted on by stale junk no longer starves
    the workflow's materializations — the session evicts the junk, and
    at drain the shared ledger equals the on-disk bytes exactly."""
    workdir = str(tmp_path)
    store = Store(os.path.join(workdir, "store"))
    junk_bytes = sum(_fill(store, f"ju{i:02d}", nfloats=2048)
                     for i in range(4))
    sess = IterativeSession(workdir, shared_budget=True,
                            storage_budget_bytes=float(junk_bytes),
                            store=store)
    rep = sess.run(_wf())
    assert rep.evictions["n_evicted"] >= 1
    assert rep.execution.materialized           # something was persisted
    ledger = StorageLedger(store.ledger_path)
    assert ledger.used() == store.total_bytes()
    # second iteration: pure reuse of what eviction admitted
    rep2 = sess.run(_wf())
    assert rep2.execution.n_computed == 0


def test_session_refuse_only_mode(tmp_path):
    """evict_to_admit=False restores refuse-on-exhausted end to end."""
    workdir = str(tmp_path)
    store = Store(os.path.join(workdir, "store"))
    junk_bytes = sum(_fill(store, f"ju{i:02d}", nfloats=2048)
                     for i in range(4))
    sess = IterativeSession(workdir, shared_budget=True,
                            storage_budget_bytes=float(junk_bytes),
                            store=store, evict_to_admit=False)
    rep = sess.run(_wf())
    assert rep.evictions == {}
    assert not rep.execution.materialized
    assert any("budget exhausted" in r
               for r in rep.execution.skipped_mat.values())
    assert all(store.has(f"ju{i:02d}") for i in range(4))


def test_sweep_eviction_ledger_matches_disk(tmp_path):
    """A budget-constrained sweep over a junk-squatted store completes
    with evictions, zero evictions of live-wanted entries (every arm's
    outputs still load on a rerun), and ledger == disk at drain."""
    from repro.core import SweepVariant, run_sweep

    workdir = str(tmp_path)
    store = Store(os.path.join(workdir, "store"))
    junk_bytes = sum(_fill(store, f"ju{i:02d}", nfloats=2048)
                     for i in range(6))
    variants = [SweepVariant(name=f"s{s}",
                             build=(lambda s=s: _wf(scale=s)),
                             knobs=s)
                for s in (1.0, 2.0, 3.0)]
    sweep = run_sweep(workdir, variants,
                      storage_budget_bytes=float(junk_bytes))
    sweep.raise_errors()
    assert sweep.evictions["n_evicted"] >= 1
    ledger = StorageLedger(store.ledger_path)
    assert ledger.used() == store.total_bytes()


# -- multiprocess evictor-vs-reader race --------------------------------------

def _evict_writer(root: str, wid: int, budget: float, q) -> None:
    """Admit a stream of new entries under a tiny shared budget: every
    admission must evict someone else's (unleased) entry, crediting the
    ledger atomically."""
    try:
        store = Store(root)
        ledger = StorageLedger(store.ledger_path)
        m = Materializer(policy=Policy.ALWAYS,
                         storage_budget_bytes=budget, ledger=ledger,
                         evictor=Evictor(store))
        value = np.full(256, float(wid))
        n_admitted = 0
        deadline = time.monotonic() + 2.0
        i = 0
        while time.monotonic() < deadline:
            sig = f"w{wid:x}i{i:04x}"
            i += 1
            est = tree_nbytes(value)
            if not m.try_reserve(est):
                continue        # everything currently leased — retry
            info = store.save(sig, f"n-{wid}", value,
                              extra_meta={"compute_s": 0.01 * wid})
            m.reconcile(est, info.nbytes)
            if info.replaced:   # unique sigs: should never happen
                m.credit_foreign(info.replaced_nbytes)
            n_admitted += 1
        q.put(("ok", wid, n_admitted, []))
    except BaseException as e:  # pragma: no cover - failure path
        q.put(("err", wid, repr(e), []))


def _pin_reader(root: str, seed: int, q) -> None:
    """Pin-and-load whatever exists; a pinned entry must never vanish
    mid-read, and values must never be torn."""
    try:
        rng = np.random.default_rng(seed)
        store = Store(root)
        n_read = 0
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            sigs = list(store.entries())
            if not sigs:
                continue
            sig = sigs[int(rng.integers(len(sigs)))]
            pin = store.acquire_read(sig)
            if pin is None:
                continue
            try:
                if not store.has(sig):
                    continue    # evicted before we pinned — acceptable
                value, _ = store.load(sig)   # pinned: must not vanish now
                assert np.all(value == value.flat[0]), "torn read"
                n_read += 1
            finally:
                pin.release()
        q.put(("ok", seed, n_read, []))
    except BaseException as e:  # pragma: no cover - failure path
        q.put(("err", seed, repr(e), []))


def test_multiprocess_evictor_vs_reader_ledger_exact(tmp_path):
    """Real OS processes: evict-to-admit writers racing pin-and-load
    readers. At drain the shared ledger must equal the sum of on-disk
    entry bytes exactly — every reserve/save/evict/credit balanced."""
    root = str(tmp_path / "store")
    store = Store(root)
    entry = store.save("seed", "seed", np.zeros(256))
    budget = 4.0 * entry.nbytes
    StorageLedger(store.ledger_path).reset(float(store.total_bytes()))

    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    procs = [ctx.Process(target=_evict_writer, args=(root, i, budget, q))
             for i in range(3)]
    procs += [ctx.Process(target=_pin_reader, args=(root, 100 + i, q))
              for i in range(2)]
    for p in procs:
        p.start()
    results = [q.get(timeout=120) for _ in procs]
    for p in procs:
        p.join(timeout=30)
    errs = [r for r in results if r[0] == "err"]
    assert not errs, errs
    assert sum(r[2] for r in results if r[1] < 100) > 0  # admissions ran

    store = Store(root, heal=True)
    ledger = StorageLedger(store.ledger_path)
    assert ledger.used() == store.total_bytes()
    assert store.total_bytes() <= budget
