"""Per-arch smoke tests (deliverable f): reduced same-family configs run one
forward + one train step on CPU; output shapes asserted, NaN-free; decode
consistency vs the full forward."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import encdec, lm, registry
from repro.train import steps

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, 4, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    return batch


@pytest.mark.parametrize("name", configs.ASSIGNED)
def test_forward_shapes_and_finite(name):
    cfg = configs.reduced(configs.get(name))
    params = registry.init(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    if cfg.family == "audio":
        out = encdec.forward(cfg, params, batch["frames"], batch["tokens"])
    else:
        out = lm.forward(cfg, params, batch["tokens"],
                         vision_embeds=batch.get("vision_embeds"),
                         mrope_positions=batch.get("mrope_positions"))
    assert out.logits.shape == (B, S if cfg.family != "audio" else S,
                                cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()


@pytest.mark.parametrize("name", configs.ASSIGNED)
def test_one_train_step(name):
    cfg = dataclasses.replace(configs.reduced(configs.get(name)),
                              grad_accum=2)
    state = steps.init_train_state(cfg, KEY)
    batch = _batch(cfg, B=4, S=16)
    new_state, metrics = jax.jit(
        lambda st, b: steps.train_step(cfg, st, b, peak_lr=1e-2,
                                       warmup_steps=1))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(new_state.params)))
    assert moved


@pytest.mark.parametrize("name", configs.ASSIGNED)
def test_prefill_decode_consistency(name):
    cfg = configs.reduced(configs.get(name))
    params = registry.init(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    if cfg.family == "audio":
        enc_out = encdec.encode(cfg, params, batch["frames"])
        full = encdec.decode(cfg, params, toks, enc_out)
        cache = registry.init_cache(cfg, B, S + 4)
        cache["enc_out"] = enc_out
        pre = encdec.decode(cfg, params, toks[:, :S - 1], enc_out, cache=cache)
        dec = encdec.decode(cfg, params, toks[:, S - 1:S], enc_out,
                            cache=pre.cache)
    else:
        full = lm.forward(cfg, params, toks)
        cache = registry.init_cache(cfg, B, S + 4)
        pre = lm.forward(cfg, params, toks[:, :S - 1], cache=cache)
        dec = lm.forward(cfg, params, toks[:, S - 1:S], cache=pre.cache)
    a = np.asarray(full.logits[:, -1], np.float32)
    b = np.asarray(dec.logits[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 3e-2, f"decode inconsistent with forward: rel err {err}"


def test_vlm_uses_vision_embeds():
    cfg = configs.reduced(configs.get("qwen2-vl-7b"))
    params = registry.init(cfg, KEY)
    batch = _batch(cfg)
    out1 = lm.forward(cfg, params, batch["tokens"],
                      vision_embeds=batch["vision_embeds"],
                      mrope_positions=batch["mrope_positions"])
    out2 = lm.forward(cfg, params, batch["tokens"],
                      vision_embeds=batch["vision_embeds"] + 1.0,
                      mrope_positions=batch["mrope_positions"])
    assert not np.allclose(np.asarray(out1.logits, np.float32),
                           np.asarray(out2.logits, np.float32))


def test_gemma3_ring_window_cache():
    """window_cache=True (ring buffers for local layers) must match the
    uniform-cache decode exactly across several steps."""
    cfg0 = configs.reduced(configs.get("gemma3-4b"))
    cfg = dataclasses.replace(cfg0, window_cache=True)
    params = registry.init(cfg, KEY)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = lm.forward(cfg0, params, toks)
    cache = lm.init_cache(cfg, B, S + 4)
    pre = lm.forward(cfg, params, toks[:, :20], cache=cache)
    scale = float(jnp.max(jnp.abs(full.logits.astype(jnp.float32)))) + 1e-9
    errs = [float(jnp.max(jnp.abs(
        pre.logits[:, -1].astype(jnp.float32)
        - full.logits[:, 19].astype(jnp.float32))))]
    c = pre.cache
    for t in range(20, S):
        out = lm.forward(cfg, params, toks[:, t:t + 1], cache=c)
        c = out.cache
        errs.append(float(jnp.max(jnp.abs(
            out.logits[:, 0].astype(jnp.float32)
            - full.logits[:, t].astype(jnp.float32)))))
    assert max(errs) < 3e-2 * scale, errs
    # and the ring cache is genuinely smaller on the real config
    import numpy as np
    real = dataclasses.replace(configs.get("gemma3-4b"), window_cache=False)
    u = jax.eval_shape(lambda: lm.init_cache(real, 1, 524288))
    w = jax.eval_shape(lambda: lm.init_cache(
        dataclasses.replace(real, window_cache=True), 1, 524288))
    nbytes = lambda t: sum(int(np.prod(l.shape)) * l.dtype.itemsize
                           for l in jax.tree_util.tree_leaves(t))
    assert nbytes(w) < 0.2 * nbytes(u)


def test_gemma3_window_pattern():
    cfg = configs.get("gemma3-4b")
    windows = [cfg.layer_window(i) for i in range(cfg.num_layers)]
    assert windows[5] is None and windows[11] is None      # global layers
    assert windows[0] == 1024 and windows[1] == 1024       # local layers
    assert sum(w is None for w in windows) == cfg.num_layers // 6


def test_jamba_structure():
    cfg = configs.get("jamba-v0.1-52b")
    attn_layers = [i for i in range(cfg.num_layers) if cfg.layer_is_attn(i)]
    assert attn_layers == [7, 15, 23, 31]                  # 1:7 ratio
    moe_layers = [i for i in range(cfg.num_layers) if cfg.layer_is_moe(i)]
    assert len(moe_layers) == 16                           # every other layer
