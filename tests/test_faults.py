"""Fault-injection recovery suite (ISSUE 6).

Drives the chaos harness (``repro.core.faults``) against the fleet
substrate and asserts the failure-recovery invariants:

* transient backend errors are retried in place (tier never degrades);
  permanent errors degrade to local-only with a cooldown re-probe and an
  escalating window, then *recover*;
* a publish crashed between "value uploaded" and "marker uploaded" is
  invisible to every reader and reclaimed by ``gc_orphans`` (age-gated);
* lease takeover after a holder crash: the TTL expires, a waiter takes
  over via conditional put, each shared signature is computed at most
  twice fleet-wide, the duplicate publish is idempotent and
  bit-identical, and the budget ledger matches on-disk bytes;
* a combined latency + transient-failure storm leaves ``run_sweep``
  outputs bit-identical to a fault-free run (and finishes — no
  deadlocks);
* server hardening: cancellation of running jobs (explicit, timeout, and
  non-drain shutdown) releases leases/reservations and reports
  ``cancelled``; the bounded admission queue answers ``busy`` with a
  retry-after the client honors; socket clients never hang (timeouts +
  chunked waits + reconnect).

Seed: ``HELIX_CHAOS_SEED`` (default 1234) drives every ``FaultPlan``;
the CI chaos job runs once with the fixed seed and once randomized,
printing the seed so failures reproduce.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import IterativeSession, compute_signatures
from repro.core.chunks import Chunked
from repro.core.config import EngineConfig, StoreConfig
from repro.core.executor import JobCancelled
from repro.core.faults import ChaosObjectStore, FaultPlan, InjectedCrash
from repro.core.omp import Policy
from repro.core.locking import HAVE_FLOCK, StorageLedger
from repro.core.remote import (FsObjectStore, RemoteStore,
                               TransientBackendError)
from repro.core.store import Store
from repro.core.sweep import SweepVariant, run_sweep
from repro.core.workflow import Workflow
from repro.serve import (FleetRouter, InProcessClient, ServerBusy,
                         connect_unix)
from repro.serve.server import SessionServer

pytestmark = pytest.mark.skipif(
    not HAVE_FLOCK, reason="fleet mode needs POSIX flock")

CHAOS_SEED = int(os.environ.get("HELIX_CHAOS_SEED", "1234"))


@pytest.fixture(autouse=True, scope="module")
def _announce_seed():
    # Printed (with -q too, on failure) so a randomized CI run is
    # reproducible: HELIX_CHAOS_SEED=<seed> pytest tests/test_faults.py
    print(f"\n[chaos] HELIX_CHAOS_SEED={CHAOS_SEED}")
    yield


def _bucket(tmp_path, name="bucket") -> FsObjectStore:
    return FsObjectStore(str(tmp_path / name))


def _value(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((32, 16)),
            "idx": np.arange(64, dtype=np.int32)}


# -- the harness itself ------------------------------------------------------

def test_fault_plan_is_deterministic_and_logged(tmp_path):
    """Same seed + same call order → same injected faults; the fired
    log records every one of them."""
    def drive(plan):
        chaos = ChaosObjectStore(_bucket(tmp_path, f"b{plan.seed}"), plan)
        outcomes = []
        for i in range(60):
            try:
                chaos.put(f"k/{i}", b"x")
                outcomes.append("ok")
            except TransientBackendError:
                outcomes.append("err")
        return outcomes

    a = drive(FaultPlan(seed=CHAOS_SEED).fail_rate("put", 0.3, times=5))
    b = drive(FaultPlan(seed=CHAOS_SEED).fail_rate("put", 0.3, times=5))
    assert a == b
    assert a.count("err") == 5

    plan = FaultPlan(seed=CHAOS_SEED).fail_nth(
        "put", 2, key_substr="entries/")
    chaos = ChaosObjectStore(_bucket(tmp_path, "blog"), plan)
    chaos.put("leases/x", b"1")        # wrong key: not a match
    chaos.put("entries/a", b"1")       # 1st match: passes
    with pytest.raises(TransientBackendError):
        chaos.put("entries/b", b"1")   # 2nd match: fires
    chaos.put("entries/b", b"1")       # rule exhausted
    assert ("error", "put", "entries/b", "TransientBackendError") \
        in plan.fired


def test_injected_faults_fire_before_side_effects(tmp_path):
    """A failed op leaves the backend untouched — injected errors have
    connection-refused semantics, so retrying them is always safe."""
    plan = FaultPlan(seed=CHAOS_SEED).fail_nth("put", 1)
    backend = _bucket(tmp_path)
    chaos = ChaosObjectStore(backend, plan)
    with pytest.raises(TransientBackendError):
        chaos.put("a/b", b"v1")
    assert backend.get("a/b") is None      # no partial write
    chaos.put("a/b", b"v1")                # the retry lands cleanly
    assert backend.get("a/b") == b"v1"


# -- retry / degrade / recover ----------------------------------------------

def test_transient_errors_retried_in_place_without_degrading(tmp_path):
    """Transient failures are absorbed by backoff+jitter retries inside
    the tier; the operation succeeds and the tier never degrades."""
    plan = FaultPlan(seed=CHAOS_SEED).fail_nth("put", 1, times=2)
    remote = RemoteStore(ChaosObjectStore(_bucket(tmp_path), plan),
                         faults=plan, heartbeats=False,
                         retry_backoff=0.01)
    try:
        remote.objects.put("entries/x/a", b"payload")  # 2 retries inside
        assert remote.objects.get("entries/x/a") == b"payload"
        assert remote.stats.n_retries == 2
        assert remote.stats.n_errors == 0
        assert remote.available()
    finally:
        remote.close()


def test_permanent_error_degrades_reprobes_and_recovers(tmp_path):
    """A permanent backend error degrades the tier for a cooldown, then
    a health re-probe recovers it; a failing probe escalates the next
    window instead of recovering."""
    plan = FaultPlan(seed=CHAOS_SEED)
    remote = RemoteStore(ChaosObjectStore(_bucket(tmp_path), plan),
                         faults=plan, heartbeats=False,
                         degrade_seconds=0.4)
    try:
        assert remote.degrade_max_seconds == pytest.approx(8 * 0.4)
        plan.fail_nth("get", 1, error="permanent")
        assert remote.marker_meta("zz99", fresh=True) is None  # trips it
        assert remote.stats.n_errors == 1
        assert not remote.available()          # inside the window
        time.sleep(0.5)
        assert remote.available()              # probe passed → recovered
        assert remote.stats.n_recoveries == 1

        # Degrade again, and this time fail the health probe too: the
        # tier must re-degrade with a doubled window, not flap back up.
        plan.fail_nth("get", 1, error="permanent")
        plan.fail_nth("exists", 1, error="permanent",
                      key_substr="health/")
        assert remote.marker_meta("zz99", fresh=True) is None
        time.sleep(0.5)
        assert not remote.available()          # probe failed
        assert remote._degrade_streak == 2     # escalated
        time.sleep(0.9)                       # doubled window passes
        assert remote.available()
        assert remote.stats.n_recoveries == 2
    finally:
        remote.close()


# -- torn publishes and orphan GC --------------------------------------------

def test_crash_between_value_and_marker_is_invisible_then_gc(tmp_path):
    """The tentpole crash window: every data object uploaded, marker
    not. Readers must see nothing; gc_orphans reclaims the bytes; the
    retried upload then commits normally."""
    plan = FaultPlan(seed=CHAOS_SEED).crash_at("upload:before_marker")
    backend = _bucket(tmp_path)
    remote = RemoteStore(backend, faults=plan, heartbeats=False)
    store = Store(str(tmp_path / "host" / "store"), remote=remote)
    info = store.save("ab12", "node", _value(3))
    with pytest.raises(InjectedCrash):
        store.upload_now("ab12")      # dies after the data, pre-marker

    # Invisible: no marker, so a fresh reader sees no entry — but the
    # orphaned data objects really are in the bucket.
    reader = RemoteStore(backend, heartbeats=False)
    assert not reader.exists("ab12")
    orphans = [k for k in backend.list("entries/ab12/")]
    assert orphans and not any(k.endswith(".complete") for k in orphans)

    # Age-gated: young objects are spared (maybe an upload in flight) …
    assert reader.gc_orphans(min_age_seconds=3600.0) == 0
    assert backend.list("entries/ab12/")
    # … old ones are reclaimed, and the ledger of record (total_bytes)
    # never counted them (uncommitted = nonexistent).
    assert reader.total_bytes(fresh=True) == 0
    assert reader.gc_orphans(min_age_seconds=0.0) == len(orphans)
    assert backend.list("entries/ab12/") == []

    # The crashed host retries (crash point disarmed): clean commit.
    assert store.upload_now("ab12")
    assert reader.marker_meta("ab12", fresh=True)["nbytes"] == info.nbytes
    reader.close()
    remote.close()


def test_interrupted_delete_leaves_only_invisible_orphans(tmp_path):
    """A delete crashed after the marker removal un-published the entry
    atomically; the leftover data objects are gc_orphans fodder."""
    plan = FaultPlan(seed=CHAOS_SEED).crash_at("delete:after_marker")
    backend = _bucket(tmp_path)
    remote = RemoteStore(backend, faults=plan, heartbeats=False)
    store = Store(str(tmp_path / "host" / "store"), remote=remote)
    store.save("cd34", "node", _value(4))
    assert store.upload_now("cd34")
    assert remote.exists("cd34")

    with pytest.raises(InjectedCrash):
        remote.delete_entry("cd34")
    reader = RemoteStore(backend, heartbeats=False)
    assert not reader.exists("cd34")               # un-published
    assert backend.list("entries/cd34/")           # data left behind
    assert reader.gc_orphans(min_age_seconds=0.0) > 0
    assert backend.list("entries/cd34/") == []
    reader.close()
    remote.close()


# -- torn chunk splices (local-tier analogue of torn uploads) ----------------

def _chunked_value(n: int = 3) -> Chunked:
    chunks = [np.arange(6, dtype=np.float64) * (i + 1) for i in range(n)]
    return Chunked(chunks=chunks,
                   chunk_sigs=tuple(f"ch{i:02d}" for i in range(n)))


def test_crash_before_manifest_leaves_invisible_chunks_then_gc(tmp_path):
    """Crash after every chunk published but before the manifest — the
    splice's commit point. Readers see nothing under the full signature;
    the orphaned chunks are age-gated GC fodder; a retry commits a
    bit-identical materialization."""
    store = Store(str(tmp_path / "store"))
    store.faults = FaultPlan(seed=CHAOS_SEED).crash_at(
        "splice:before_manifest")
    value = _chunked_value()
    with pytest.raises(InjectedCrash):
        store.save("full-sig", "node", value)

    # Invisible: no manifest, so the full signature does not exist —
    # but the chunk entries really are on disk.
    assert not store.has_local("full-sig")
    orphans = [s for s, e in store.entries().items() if e.get("is_chunk")]
    assert len(orphans) == 3
    # Age-gated: young chunks are spared (maybe a splice in flight) …
    assert store.gc_orphan_chunks(min_age_seconds=3600.0) == (0, 0)
    # … old ones are reclaimed.
    n, freed = store.gc_orphan_chunks(min_age_seconds=0.0)
    assert n == 3 and freed > 0
    assert store.total_bytes() == 0

    # The retried splice (crash point disarmed) commits normally.
    store.save("full-sig", "node", value)
    out, _ = store.load("full-sig")
    assert out.assemble().tobytes() == value.assemble().tobytes()


def test_crash_mid_chunk_publish_retry_is_dedup_aware(tmp_path):
    """Crash after the second of three chunks published. The retry must
    skip the already-present chunks (content-addressed dedup) and its
    SaveInfo must count exactly the bytes it added to disk — the
    property the fleet ledger relies on."""
    store = Store(str(tmp_path / "store"))
    store.faults = FaultPlan(seed=CHAOS_SEED).crash_at(
        "splice:chunk_published", nth=2)
    value = _chunked_value()
    with pytest.raises(InjectedCrash):
        store.save("full-sig", "node", value)
    assert not store.has_local("full-sig")
    assert sum(1 for e in store.entries().values()
               if e.get("is_chunk")) == 2

    before = store.total_bytes()
    info = store.save("full-sig", "node", value)
    assert info.nbytes == store.total_bytes() - before   # dedup-aware
    out, _ = store.load("full-sig")
    assert out.assemble().tobytes() == value.assemble().tobytes()
    # Referenced chunks are no longer orphans: GC must spare them all.
    assert store.gc_orphan_chunks(min_age_seconds=0.0) == (0, 0)


def test_crash_with_memory_only_entry_recovers_clean(tmp_path):
    """Process death while a write-back entry is resident only in RAM:
    the entry dies with the process — no disk bytes, no ledger charge,
    and a restarted store sees a clean miss that recomputes normally."""
    store = Store(str(tmp_path / "store"), mem_budget_bytes=64e6,
                  mem_writeback=True)
    StorageLedger(store.ledger_path).ensure(0.0)
    store.save("ab12", "node", _value(5))
    assert store.mem_has("ab12") and not store.has_local("ab12")
    assert store.total_bytes() == 0
    assert StorageLedger(store.ledger_path).used() == 0

    # "kill -9": the first store's RAM vanishes; a fresh process opens
    # the same workdir and must see no trace of the signature.
    survivor = Store(str(tmp_path / "store"), mem_budget_bytes=64e6)
    assert not survivor.has("ab12")
    assert survivor.total_bytes() == 0
    assert StorageLedger(survivor.ledger_path).used() == 0   # no drift

    # Clean recompute: the rerun saves write-through and stays consistent.
    survivor.save("ab12", "node", _value(5))
    got, _ = survivor.load("ab12")
    np.testing.assert_array_equal(got["w"], _value(5)["w"])


def test_crash_before_spill_is_invisible_and_retry_reconciles(tmp_path):
    """Crash at ``memtier:before_spill`` — demotion decided, zero
    durable bytes written. The torn spill must be invisible (no entry,
    no partial files after heal, ledger == disk == 0) and the retried
    save + flush must leave ledger == disk."""
    value_a = np.arange(1500, dtype=np.float64)      # 12KB each
    value_b = np.arange(1500, 3000, dtype=np.float64)
    store = Store(str(tmp_path / "store"), mem_budget_bytes=20_000,
                  mem_writeback=True)
    StorageLedger(store.ledger_path).ensure(0.0)
    store.faults = FaultPlan(seed=CHAOS_SEED).crash_at(
        "memtier:before_spill")
    store.save("aa11", "a", value_a)
    with pytest.raises(InjectedCrash):
        store.save("bb22", "b", value_b)             # evicts aa11 → spill

    # A fresh process (heal reaps any .tmp- staging) sees nothing.
    survivor = Store(str(tmp_path / "store"), mem_budget_bytes=20_000,
                     mem_writeback=True)
    assert not survivor.has("aa11") and not survivor.has("bb22")
    assert survivor.total_bytes() == 0
    assert not [d for d in os.listdir(survivor.root)
                if d.startswith(".tmp-")]
    assert StorageLedger(survivor.ledger_path).used() == 0

    # Retry: recompute both, force everything durable — ledger == disk.
    survivor.save("aa11", "a", value_a)
    survivor.save("bb22", "b", value_b)
    survivor.mem_flush()
    assert survivor.has_local("aa11") and survivor.has_local("bb22")
    assert (StorageLedger(survivor.ledger_path).used()
            == survivor.total_bytes() > 0)
    got, _ = survivor.load("aa11")
    np.testing.assert_array_equal(got, value_a)


def test_crash_after_spill_left_entry_committed_and_ledger_true(tmp_path):
    """Crash at ``memtier:after_spill`` — the spilled entry is already
    published and its bytes already adjusted into the fleet ledger, so
    a restarted store finds a complete, consistent disk tier with
    nothing left to redo."""
    value_a = np.arange(1500, dtype=np.float64)
    store = Store(str(tmp_path / "store"), mem_budget_bytes=20_000,
                  mem_writeback=True)
    StorageLedger(store.ledger_path).ensure(0.0)
    store.faults = FaultPlan(seed=CHAOS_SEED).crash_at(
        "memtier:after_spill")
    store.save("aa11", "a", value_a)
    with pytest.raises(InjectedCrash):
        store.save("bb22", "b",
                   np.arange(1500, 3000, dtype=np.float64))

    survivor = Store(str(tmp_path / "store"), mem_budget_bytes=20_000)
    assert survivor.has_local("aa11")                # spill committed
    assert (StorageLedger(survivor.ledger_path).used()
            == survivor.total_bytes() > 0)           # already adjusted
    got, _ = survivor.load("aa11")
    np.testing.assert_array_equal(got, value_a)


def test_session_splice_crash_retry_commits_bit_identical(tmp_path):
    """End-to-end: a delta run dies mid-splice; the surviving partial
    state is invisible to readers, the retried run commits bit-identical
    to a cold recompute, and the fleet ledger equals on-disk bytes."""
    def build(descs):
        wf = Workflow("splice")
        src = wf.source(
            "src", lambda d=list(descs):
            [np.random.default_rng(s).standard_normal(n) for s, n in d],
            chunks=list(descs))
        m = wf.extractor("m", lambda x: np.cos(x), [src],
                         config="m", incremental="map")
        wf.output(m)
        return wf

    def session(path):
        return IterativeSession(path,
                                engine=EngineConfig(policy=Policy.ALWAYS),
                                storage=StoreConfig(shared_budget=True))

    sess = session(str(tmp_path / "inc"))
    d0 = [(1, 20), (2, 20)]
    sess.run(build(d0))
    d1 = d0 + [(3, 20)]
    sess.store.faults = FaultPlan(seed=CHAOS_SEED).crash_at(
        "splice:before_manifest")
    with pytest.raises(InjectedCrash):
        sess.run(build(d1))
    sess.store.faults = None

    rep = sess.run(build(d1))
    cold = session(str(tmp_path / "cold"))
    crep = cold.run(build(d1))
    assert np.asarray(rep.outputs["m"]).tobytes() \
        == np.asarray(crep.outputs["m"]).tobytes()
    assert StorageLedger(sess.store.ledger_path).used() \
        == pytest.approx(float(sess.store.total_bytes()))


# -- lease takeover after a crash --------------------------------------------

def _shared_workflow(tag: str, calls: dict, lock: threading.Lock):
    """src → feat (shared, counted) → per-tag tail."""
    def count(name):
        with lock:
            calls[name] = calls.get(name, 0) + 1

    wf = Workflow("takeover")
    src = wf.source(
        "src", lambda: (count("src"),
                        np.arange(512, dtype=np.float64))[1],
        config="v1")

    def featurize(x):
        count("feat")
        return np.tanh(x.reshape(16, 32) @ x.reshape(32, 16))

    feat = wf.extractor("feat", featurize, [src], config="v1")
    out = wf.reducer(
        "out", lambda z, t=tag: {"score": float(np.sum(z)), "tag": t},
        [feat], config=("tail", tag))
    wf.output(out)
    return wf


def test_lease_takeover_compute_at_most_twice_and_idempotent(tmp_path):
    """Satellite 3 + tentpole invariant. A holder crashes mid-compute
    (heartbeat never renews): the TTL lease expires, the waiting host
    takes over via conditional put and computes; fleet-wide each shared
    signature is computed at most twice (crashed + taker). When the
    crashed host resurfaces and publishes its duplicate, the publish is
    idempotent — one committed entry, bit-identical — and the taker's
    budget ledger matches its on-disk bytes."""
    backend = _bucket(tmp_path)
    calls: dict = {}
    lock = threading.Lock()
    sigs = compute_signatures(_shared_workflow("h", {}, lock).build())
    shared_sig = sigs["feat"]

    # Host A: takes the fleet compute lease, then "crashes" — its
    # heartbeats never run, so the lease object silently expires.
    crashed_remote = RemoteStore(backend, lease_ttl=0.4, heartbeats=False)
    crashed_store = Store(str(tmp_path / "crashed" / "store"),
                          remote=crashed_remote)
    held = crashed_store.acquire_compute(shared_sig)
    assert held is not None

    # Host B: a full session with in-flight dedupe. Its dedupe loop
    # waits on the lease, sees it expire, takes over, computes once.
    store_b = Store(str(tmp_path / "hostB" / "store"),
                    remote=RemoteStore(backend, lease_ttl=0.4))
    sess_b = IterativeSession(str(tmp_path / "hostB"),
                              dedupe_inflight=True, shared_budget=True,
                              dedupe_wait_seconds=30.0, store=store_b)
    t0 = time.monotonic()
    report = sess_b.run(_shared_workflow("b", calls, lock),
                        share_sigs=frozenset([shared_sig]))
    assert time.monotonic() - t0 < 30.0       # takeover, not timeout
    store_b.writer_drain()
    assert calls["feat"] == 1                 # taker computed it once
    assert report.outputs["out"]["tag"] == "b"
    taker_value, _ = store_b.load(shared_sig)
    reader = RemoteStore(backend, heartbeats=False)
    assert reader.exists(shared_sig)          # published for the fleet

    # The crashed host resurfaces: it finishes its duplicate compute
    # (fleet-wide total now 2 — "at most twice") and publishes. The
    # marker-exists check makes that a no-op: still one entry,
    # bit-identical to the taker's.
    calls_a: dict = {}
    wf_a = _shared_workflow("a", calls_a, lock)
    dup_value = wf_a.build().nodes["feat"].fn(
        wf_a.build().nodes["src"].fn())
    crashed_store.save(shared_sig, "feat", dup_value)
    assert crashed_store.upload_now(shared_sig)   # idempotent: marker won
    meta = reader.marker_meta(shared_sig, fresh=True)
    markers = [k for k in backend.list(f"entries/{shared_sig}/")
               if k.endswith(".complete")]
    assert len(markers) == 1
    np.testing.assert_array_equal(taker_value, dup_value)

    # Ledger == on-disk bytes on the surviving host after the storm.
    assert StorageLedger(store_b.ledger_path).used() \
        == pytest.approx(float(store_b.total_bytes()))
    assert meta["nbytes"] > 0
    held.release()
    reader.close()
    crashed_remote.close()
    store_b.remote.close()


def test_dropped_heartbeats_expire_lease_under_live_holder(tmp_path):
    """A GC-paused holder (scripted heartbeat drops) loses the lease:
    the sibling acquires after the TTL even though the holder process
    is still alive."""
    backend = _bucket(tmp_path)
    plan = FaultPlan(seed=CHAOS_SEED).drop_heartbeats(50)
    holder = RemoteStore(backend, lease_ttl=0.3, faults=plan)
    sibling = RemoteStore(backend, lease_ttl=0.3, heartbeats=False)
    try:
        lease = holder.acquire_compute("ee55")
        assert lease is not None
        assert sibling.acquire_compute("ee55") is None   # live at first
        deadline = time.monotonic() + 5.0
        taken = None
        while taken is None and time.monotonic() < deadline:
            time.sleep(0.1)
            taken = sibling.acquire_compute("ee55")
        assert taken is not None, "dropped heartbeats never expired lease"
        assert ("heartbeat_drop",) in plan.fired
        taken.release()
        lease.release()       # stale release is harmless (lease.lost)
    finally:
        sibling.close()
        holder.close()


# -- fault storm: end-to-end equivalence -------------------------------------

def _storm_variants(k=3):
    lock = threading.Lock()
    return [SweepVariant(name=f"v{i}",
                         build=(lambda t=f"v{i}": _shared_workflow(
                             t, {}, lock)))
            for i in range(k)]


def test_fault_storm_sweep_bit_identical_to_fault_free(tmp_path):
    """Acceptance: a 2-host sweep under a combined latency + transient
    failure storm completes (no hangs), errors nothing, and produces
    outputs bit-identical to the fault-free run — the retry/degrade
    machinery is invisible to results. Ledgers match disk on each host
    afterwards (no reservation leaks under injected failures)."""
    clean = run_sweep(str(tmp_path / "clean"), _storm_variants(),
                      n_hosts=2, remote=str(tmp_path / "clean_bucket"))
    clean.raise_errors()

    plan = (FaultPlan(seed=CHAOS_SEED)
            .fail_rate(None, 0.05, error="transient", times=200)
            .add_latency("put", 0.002, jitter=0.002)
            .add_latency("get", 0.002, jitter=0.002))
    stormy_remote = RemoteStore(
        ChaosObjectStore(_bucket(tmp_path, "storm_bucket"), plan),
        faults=plan, retry_backoff=0.01)
    storm = run_sweep(str(tmp_path / "storm"), _storm_variants(),
                      n_hosts=2, remote=stormy_remote)
    storm.raise_errors()
    assert storm.outputs == clean.outputs
    assert plan.fired, "the storm plan never injected anything"

    for host in ("host0", "host1"):
        root = str(tmp_path / "storm" / host / "store")
        store = Store(root)
        ledger = StorageLedger(store.ledger_path)
        assert ledger.used() == pytest.approx(float(store.total_bytes()))
    stormy_remote.close()


# -- server hardening: cancellation, timeout, backpressure -------------------

def _chain_registry(n=24, delay=0.08):
    """A registry whose one workflow is an n-node sleeping chain —
    long enough to cancel mid-run, with plenty of between-node checks.
    ``tag`` shifts every signature, so a resubmission with a fresh tag
    really recomputes instead of loading the previous run's entries."""
    def build(tag="t0"):
        wf = Workflow("chain")
        prev = wf.source("n0", lambda: np.float64(1.0),
                         config=("v1", tag))
        for i in range(1, n):
            prev = wf.extractor(
                f"n{i}",
                lambda x, d=delay: (time.sleep(d), x + 1.0)[1],
                [prev], config=("v1", tag))
        out = wf.reducer("out", lambda x: {"v": float(x)}, [prev],
                         config=("tail", tag))
        wf.output(out)
        return wf
    return {"chain": build}


def _wait_status(job, status, timeout=10.0):
    deadline = time.monotonic() + timeout
    while job.status != status and time.monotonic() < deadline:
        time.sleep(0.02)
    assert job.status == status, f"job stuck in {job.status!r}"


def test_cancel_running_job_releases_everything(tmp_path):
    """Cancelling a running job stops it between nodes with status
    ``cancelled`` (not ``error``), drops every lease, keeps the ledger
    honest, and leaves the server healthy for the next submission."""
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(), n_sessions=2,
                           storage_budget_bytes=float(10 * 2 ** 20))
    try:
        job = server.submit_named("chain")
        _wait_status(job, "running")
        time.sleep(0.2)                      # let a few nodes finish
        assert server.cancel(job.id) is True
        server.wait(job, timeout=15.0)
        assert job.status == "cancelled"
        assert isinstance(job.error, JobCancelled)
        assert server.cancel(job.id) is False     # idempotent: finished
        assert server.job_summary(job)["status"] == "cancelled"
        assert server.status()["cancelled"] == 1

        counts = server.store.lease_counts()
        assert counts == {"compute": 0, "pins": 0, "waiters": 0}
        assert StorageLedger(server.store.ledger_path).used() \
            == pytest.approx(float(server.store.total_bytes()))

        # The server is not poisoned: the same workflow now completes
        # (and reuses whatever prefix the cancelled run materialized).
        job2 = server.submit_named("chain")
        server.wait(job2, timeout=60.0)
        assert job2.status == "done"
    finally:
        server.shutdown()


def test_cancel_queued_job_never_runs(tmp_path):
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(), n_sessions=1)
    try:
        running = server.submit_named("chain")
        _wait_status(running, "running")
        queued = server.submit_named("chain")
        assert queued.status == "queued"
        assert server.cancel(queued.id) is True
        assert queued.status == "cancelled"
        assert queued.done.is_set()
        server.cancel(running.id)
        server.wait(running, timeout=15.0)
    finally:
        server.shutdown()


def test_job_timeout_reports_cancelled(tmp_path):
    """A per-submission timeout fires the cancel flag server-side: the
    job stops between nodes and reports ``cancelled``."""
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(n=40, delay=0.1),
                           n_sessions=1)
    try:
        job = server.submit_named("chain", timeout=0.4)
        server.wait(job, timeout=20.0)
        assert job.status == "cancelled"
        assert isinstance(job.error, JobCancelled)
        assert job.run_seconds < 15.0
    finally:
        server.shutdown()


def test_shutdown_nodrain_cancels_running_jobs(tmp_path):
    """Satellite 1: shutdown(drain=False) stops *running* jobs through
    the cancel flag — promptly, and reported as cancelled."""
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(n=60, delay=0.1),
                           n_sessions=2)
    running = server.submit_named("chain")
    queued_behind = [server.submit_named("chain") for _ in range(3)]
    _wait_status(running, "running")
    t0 = time.monotonic()
    server.shutdown(drain=False)
    assert time.monotonic() - t0 < 20.0          # did not sit out 6 s/job
    assert running.status == "cancelled"
    assert isinstance(running.error, JobCancelled)
    for j in queued_behind:
        assert j.status == "cancelled"
        assert j.done.is_set()


def test_bounded_queue_busy_and_client_retry(tmp_path):
    """Backpressure: a full admission queue answers busy-with-retry-
    after; the client retries automatically and lands the submit once a
    slot frees."""
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(n=10, delay=0.05),
                           n_sessions=1, max_queue=1,
                           busy_retry_after=0.05)
    try:
        first = server.submit_named("chain")
        _wait_status(first, "running")
        server.submit_named("chain")             # fills the queue
        with pytest.raises(ServerBusy) as exc:
            server.submit_named("chain")         # bounced
        assert exc.value.retry_after == pytest.approx(0.05)
        assert server.status()["max_queue"] == 1

        # The wire shape: ok=false + busy=true + retry_after; the client
        # turns it into automatic retries that eventually succeed.
        client = InProcessClient(server)
        client.busy_retries = 200
        job_id = client.submit("chain")          # blocks through busy
        assert client.wait(job_id, timeout=60.0)["status"] == "done"
    finally:
        server.shutdown()


def test_socket_client_timeouts_chunked_wait_and_cancel(tmp_path):
    """A socket client with a short RPC timeout survives a job that
    runs much longer than the timeout (chunked waits), cancels jobs
    over the wire, and never hangs on a shut-down server."""
    server = SessionServer(str(tmp_path / "srv"),
                           registry=_chain_registry(n=14, delay=0.1),
                           n_sessions=1)
    path = server.serve_unix(str(tmp_path / "helix.sock"))
    client = connect_unix(path, timeout=0.5)
    try:
        job = client.submit("chain")
        summary = client.wait(job)               # ~1.4 s ≫ 0.5 s timeout
        assert summary["status"] == "done"
        assert summary["outputs"]["out"]["v"] == 14.0

        # Fresh tags below: same-tag resubmissions would load the first
        # run's materializations and finish instantly.
        job2 = client.submit("chain", {"tag": "doomed"}, name="doomed")
        assert client.cancel(job2) is True
        assert client.wait(job2, timeout=30.0)["status"] == "cancelled"
        assert client.cancel(job2) is False      # already finished

        # A wait whose overall deadline expires raises TimeoutError on
        # the client — distinct from the ServerError a dead job gives.
        job3 = client.submit("chain", {"tag": "slow"})
        with pytest.raises(TimeoutError):
            client.wait(job3, timeout=0.2)
        assert client.cancel(job3) is True
        assert client.wait(job3, timeout=30.0)["status"] == "cancelled"
        client.shutdown()
    finally:
        client.close()
        server.shutdown()


def test_gc_orphans_scheduled_by_owning_server(tmp_path):
    """Satellite 2: the server's maintenance thread runs gc_orphans
    periodically with the min-age guard; crash orphans disappear
    without any client asking."""
    backend = _bucket(tmp_path)
    backend.put("entries/dead01/w.npy", b"x" * 128)   # crashed publish
    backend.put("entries/dead01/meta.json", b"{}")
    server = SessionServer(str(tmp_path / "srv"),
                           remote=RemoteStore(backend, heartbeats=False),
                           gc_interval=0.1, gc_min_age=0.0)
    try:
        deadline = time.monotonic() + 10.0
        while backend.list("entries/dead01/") \
                and time.monotonic() < deadline:
            time.sleep(0.05)
        assert backend.list("entries/dead01/") == []
        status = server.status()
        assert status["gc"]["runs"] >= 1
        assert status["gc"]["reclaimed"] >= 2
    finally:
        server.shutdown()
        server.store.remote.close()


def test_gc_disabled_without_remote_or_interval(tmp_path):
    """No remote tier (or gc_interval=0) → no maintenance thread."""
    local_only = SessionServer(str(tmp_path / "a"))
    disabled = SessionServer(str(tmp_path / "b"),
                             remote=str(tmp_path / "bucket"),
                             gc_interval=0)
    try:
        assert local_only._maintenance is None
        assert local_only.gc_interval == 0.0
        assert disabled._maintenance is None
        # default interval documented at 900 s when a remote exists
        with_remote = SessionServer(str(tmp_path / "c"),
                                    remote=str(tmp_path / "bucket2"))
        assert with_remote.gc_interval == 900.0
        assert with_remote._maintenance is not None
        with_remote.shutdown()
    finally:
        disabled.shutdown()
        local_only.shutdown()


# -- fleet router: shard death, failover, rebalance (ISSUE 10) ---------------

def _slow_family_registry(calls, work=600, delay=0.08):
    """One workflow: heavy counted prefix + an optional sleeping tail.

    ``tail=0`` is the warm arm (prefix only, fast); ``tail=N`` appends N
    sleeping extractors so a second submission can be killed mid-run.
    Both share the same source node, hence the same route key — the
    router must place them on the same shard."""
    def build(family="x", reg=0.1, tail=0):
        wf = Workflow(f"slow-{family}-{reg}-{tail}")
        src = wf.source(
            "src",
            lambda: np.arange(4096, dtype=np.float64).reshape(64, 64),
            config=("v1", family))

        def featurize(m):
            calls.hit(f"feat_{family}")
            acc = m.copy()
            for _ in range(work):
                acc = np.tanh(acc @ m.T @ m / m.size)
            return acc

        prev = wf.extractor("feat", featurize, [src],
                            config=("feat", family))
        for i in range(tail):
            prev = wf.extractor(
                f"t{i}", lambda x, d=delay: (time.sleep(d), x)[1],
                [prev], config=("tail", i))
        out = wf.reducer("out", lambda m, r=reg: {"v": float(np.sum(m)) * r},
                         [prev], config=("eval", reg))
        wf.output(out)
        return wf
    return {"slow": build}


class _Calls:
    """Thread-safe per-node compute counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts: dict[str, int] = {}

    def hit(self, name: str) -> None:
        with self._lock:
            self.counts[name] = self.counts.get(name, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self.counts.get(name, 0)


def test_shard_death_mid_job_fails_over_compute_once(tmp_path):
    """Kill a shard mid-job: the router detects the shutdown-cancel,
    fails over through the cancellation/retry path, and the job finishes
    on the survivor — with the warm prefix *fetched* from the shared
    remote tier, not recomputed (compute-once holds fleet-wide across
    the failover). The survivor's ledger still matches its disk."""
    calls = _Calls()
    registry = _slow_family_registry(calls)
    servers = {}
    for sid in ("s0", "s1"):
        servers[sid] = SessionServer(
            str(tmp_path / sid), registry=registry,
            remote=RemoteStore(_bucket(tmp_path)), n_sessions=1,
            poll_interval=0.01)
    router = FleetRouter(servers, registry=registry)
    try:
        # warm the prefix through the router, publish it to the remote
        warm = router.submit("slow", {"family": "x", "reg": 0.1,
                                      "tail": 0})
        out = router.wait(warm, timeout=60.0)
        assert out["status"] == "done"
        owner = out["shard"]
        assert calls.get("feat_x") == 1
        servers[owner].store.writer_drain()     # uploads committed

        # same prefix + a sleepy tail: routed to the same (warm) shard
        victim = router.submit("slow", {"family": "x", "reg": 0.1,
                                        "tail": 24})
        assert router._jobs[victim]["shard"] == owner
        _wait_status(servers[owner]._jobs[victim], "running")
        time.sleep(0.2)                         # a few tail nodes in

        servers[owner].shutdown(drain=False)    # the shard dies mid-job
        out = router.wait(victim, timeout=120.0)
        assert out["status"] == "done"
        survivor = out["shard"]
        assert survivor != owner
        assert router.failovers == 1
        assert out["outputs"]["out"]["v"] == pytest.approx(
            float(np.sum(_slow_prefix_value())) * 0.1)

        # compute-once across the failover: the survivor fetched the
        # published prefix instead of recomputing it
        assert calls.get("feat_x") == 1
        # the survivor's ledger matches the bytes actually on its disk
        assert StorageLedger(servers[survivor].store.ledger_path).used() \
            == pytest.approx(float(servers[survivor].store.total_bytes()))
        counts = servers[survivor].store.lease_counts()
        assert counts == {"compute": 0, "pins": 0, "waiters": 0}
        # the router reports the dead shard and the healthy one
        snap = router.status()
        assert snap["failovers"] == 1
        assert snap["shards"][owner].get("dead") is True
        assert snap["shards"][survivor]["accepting"]
    finally:
        router.close()
        for srv in servers.values():
            srv.shutdown()


def _slow_prefix_value():
    """The featurized matrix `_slow_family_registry` computes (work=600)."""
    m = np.arange(4096, dtype=np.float64).reshape(64, 64)
    acc = m.copy()
    for _ in range(600):
        acc = np.tanh(acc @ m.T @ m / m.size)
    return acc


def test_shard_rejoin_rebalances_only_rendezvous_moved_keys(tmp_path):
    """Removing one of N shards re-homes only that shard's keys — an
    expected 1/N of the keyspace — and re-adding it restores the exact
    original placement (no other key ever moves)."""
    servers = {f"s{i}": SessionServer(str(tmp_path / f"s{i}"),
                                      poll_interval=0.01)
               for i in range(4)}
    router = FleetRouter(servers)
    try:
        rng = np.random.default_rng(CHAOS_SEED)
        keys = [bytes(rng.bytes(16)).hex() for _ in range(240)]
        before = {k: router.shard_for(k) for k in keys}
        assert set(before.values()) == set(servers)   # all shards used

        router.remove_shard("s2")
        after = {k: router.shard_for(k) for k in keys}
        moved = [k for k in keys if before[k] != after[k]]
        # only s2's keys moved, and every one of them moved off s2
        assert set(moved) == {k for k in keys if before[k] == "s2"}
        assert all(after[k] != "s2" for k in moved)
        # the move fraction is ~1/4 (binomial slack for 240 keys)
        assert 0.10 <= len(moved) / len(keys) <= 0.45

        router.add_shard("s2", servers["s2"])
        restored = {k: router.shard_for(k) for k in keys}
        assert restored == before                     # exact rebalance
    finally:
        router.close()
        for srv in servers.values():
            srv.shutdown()
