"""Memory tier of the TierStack (ISSUE 9): zero-copy hits, demotion
accounting, write-back spills, ml_dtypes cross-tier bit-identity.

Correctness bar:

* a same-process hit serves the *same host pytree object* back with zero
  ``.npy`` leaf reads (the zero-copy contract), bit-identical to a disk
  reload by a memory-less Store and to a remote read-through on a fresh
  host — including bf16/fp8 leaves that ride the ``_npy_storage_view``
  uint reinterpretation on disk;
* the memory budget is enforced by demote-not-delete eviction: entries
  pushed out of RAM remain loadable from disk, and the tier's byte
  accounting equals a recount of what is actually resident (the per-tier
  ledger==bytes-held invariant) through arbitrary churn;
* write-back mode keeps saves memory-only (``SaveInfo.nbytes == 0``,
  nothing on disk, no ledger charge) until ``mem_flush`` or demotion
  spills them — at which point ledger == disk again;
* ``tier_status`` speaks one schema for every tier.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.locking import HAVE_FLOCK
from repro.core.remote import FsObjectStore, RemoteStore
from repro.core.store import StorageLedger, Store


def _mem_store(root, budget=64e6, **kw) -> Store:
    return Store(str(root), mem_budget_bytes=budget, **kw)


def _ml_dtypes_value() -> dict:
    """A pytree whose array leaves exercise the uint-view .npy path."""
    import ml_dtypes

    rng = np.random.default_rng(3)
    f32 = rng.standard_normal((32, 16)).astype(np.float32)
    return {
        "bf16": jnp.asarray(f32, jnp.bfloat16),
        "fp8": f32.astype(ml_dtypes.float8_e4m3fn),
        "f32": f32,
        "tag": "mixed",
    }


def _assert_leaves_identical(got: dict, want: dict) -> None:
    for k in ("bf16", "fp8", "f32"):
        g, w = np.asarray(got[k]), np.asarray(want[k])
        assert g.dtype == w.dtype, f"{k}: dtype {g.dtype} != {w.dtype}"
        # bit-level comparison: uint views sidestep NaN!=NaN semantics
        np.testing.assert_array_equal(
            g.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[g.itemsize]),
            w.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[w.itemsize]),
            err_msg=f"leaf {k} not bit-identical")
    assert got["tag"] == want["tag"]


# -- zero-copy hits ----------------------------------------------------------

def test_memory_hit_is_zero_copy_and_skips_npy(tmp_path):
    store = _mem_store(tmp_path)
    value = {"w": np.arange(4096, dtype=np.float64), "k": 7}
    store.save("ab12", "node", value)
    reads0 = store.npy_leaf_reads
    got, secs = store.load("ab12")
    # same host objects back, not a deserialized copy, no disk I/O
    assert got["w"] is value["w"] and got["k"] == 7
    assert store.npy_leaf_reads == reads0
    assert store.load_stats["memory"]["hits"] == 1
    assert store.load_stats["local"]["hits"] == 0
    assert secs >= 0


def test_memory_hit_matches_disk_reload_ml_dtypes(tmp_path):
    value = _ml_dtypes_value()
    store = _mem_store(tmp_path)
    store.save("ab12", "node", value)
    store.writer_drain()

    mem_got, _ = store.load("ab12")               # memory-served
    assert store.load_stats["memory"]["hits"] == 1
    disk_store = Store(str(tmp_path))             # mem off: forces .npy
    disk_got, _ = disk_store.load("ab12")
    assert disk_store.npy_leaf_reads > 0

    _assert_leaves_identical(mem_got, value)
    _assert_leaves_identical(disk_got, value)
    _assert_leaves_identical(mem_got, disk_got)


@pytest.mark.skipif(not HAVE_FLOCK, reason="fleet mode needs POSIX flock")
def test_remote_read_through_promotes_to_memory_ml_dtypes(tmp_path):
    """Host A write-through; host B read-through must be bit-identical
    and land the value in B's memory tier (next load is a RAM hit)."""
    fs = FsObjectStore(str(tmp_path / "bucket"))
    value = _ml_dtypes_value()
    store_a = _mem_store(tmp_path / "hostA", remote=RemoteStore(fs))
    store_a.save("ab12", "node", value)
    store_a.writer_drain()
    assert store_a.remote.exists("ab12")

    store_b = _mem_store(tmp_path / "hostB", remote=RemoteStore(fs))
    got, _ = store_b.load("ab12")                 # remote fetch
    _assert_leaves_identical(got, value)
    assert store_b.load_stats["remote"]["hits"] == 1
    assert store_b.mem_has("ab12")                # promoted on the way in
    reads = store_b.npy_leaf_reads
    again, _ = store_b.load("ab12")               # now a RAM hit
    assert store_b.npy_leaf_reads == reads
    assert store_b.load_stats["memory"]["hits"] == 1
    _assert_leaves_identical(again, got)


def test_disk_promotion_on_local_load(tmp_path):
    """A cold-process load populates the memory tier (read-through
    promotion): the second load of the same signature skips .npy."""
    seed = Store(str(tmp_path))
    seed.save("ab12", "node", {"x": np.ones(512)})
    store = _mem_store(tmp_path)
    assert not store.mem_has("ab12")
    store.load("ab12")
    assert store.mem_has("ab12")
    reads = store.npy_leaf_reads
    store.load("ab12")
    assert store.npy_leaf_reads == reads


# -- budget / demotion accounting --------------------------------------------

def test_demote_not_delete_and_ledger_invariant(tmp_path):
    """Churn far past the memory budget: entries are demoted (never
    lost — disk still serves them) and bytes-held always equals a
    recount of what is resident."""
    store = _mem_store(tmp_path, budget=40_000)
    rng = np.random.default_rng(0)
    for i in range(12):
        store.save(f"sig{i:02d}", f"n{i}",
                   rng.standard_normal(1024))       # ~8KB each
        assert store._mem.bytes_held == store._mem.recount()
        assert store._mem.bytes_held <= 40_000
    status = store.tier_status()["memory"]
    assert status["demotions"] > 0
    assert status["bytes"] == store._mem.recount()
    # demoted != deleted: every signature still loads, bit-identically
    rng = np.random.default_rng(0)
    for i in range(12):
        got, _ = store.load(f"sig{i:02d}")
        np.testing.assert_array_equal(got, rng.standard_normal(1024))


def test_oversized_value_bypasses_memory_tier(tmp_path):
    store = _mem_store(tmp_path, budget=1_000)
    store.save("ab12", "big", np.ones(4096))        # 32KB > budget
    assert not store.mem_has("ab12")
    assert store.has_local("ab12")                  # disk took it
    got, _ = store.load("ab12")
    np.testing.assert_array_equal(got, np.ones(4096))


def test_delete_drops_memory_entry(tmp_path):
    store = _mem_store(tmp_path)
    store.save("ab12", "node", np.ones(64))
    assert store.mem_has("ab12")
    store.delete("ab12")
    assert not store.mem_has("ab12") and not store.has("ab12")


# -- write-back mode ---------------------------------------------------------

def test_writeback_save_is_memory_only_until_flush(tmp_path):
    store = _mem_store(tmp_path, mem_writeback=True)
    # Seed a fleet ledger: the spill path must adjust it to mirror the
    # disk (nobody reserved the spilled bytes — honesty over overshoot).
    StorageLedger(store.ledger_path).ensure(0.0)
    info = store.save("ab12", "node", {"x": np.ones(256)})
    assert info.nbytes == 0                         # no disk charge yet
    assert store.mem_has("ab12") and not store.has_local("ab12")
    assert store.has("ab12")                        # tier-wide presence
    assert store.total_bytes() == 0
    got, _ = store.load("ab12")
    np.testing.assert_array_equal(got["x"], np.ones(256))

    n = store.mem_flush()                           # durability barrier
    assert n == 1
    assert store.has_local("ab12")
    assert store.tier_status()["memory"]["dirty"] == 0
    # ledger == disk after the spill
    ledger = StorageLedger(store.ledger_path).used()
    assert ledger == store.total_bytes() > 0
    disk_got, _ = Store(str(tmp_path)).load("ab12")
    np.testing.assert_array_equal(disk_got["x"], np.ones(256))


def test_writeback_demotion_spills_dirty_entry(tmp_path):
    """Evicting a dirty entry must spill it to disk, not lose it."""
    store = _mem_store(tmp_path, budget=20_000, mem_writeback=True)
    StorageLedger(store.ledger_path).ensure(0.0)
    a = np.arange(1500, dtype=np.float64)           # 12KB
    b = np.arange(1500, 3000, dtype=np.float64)
    store.save("aa11", "a", a)
    store.save("bb22", "b", b)                      # evicts aa11 → spill
    assert store.has_local("aa11")
    assert StorageLedger(store.ledger_path).used() == store.total_bytes()
    got, _ = store.load("aa11")
    np.testing.assert_array_equal(got, a)
    got, _ = store.load("bb22")
    np.testing.assert_array_equal(got, b)


def test_writeback_delete_purges_memory_only_entry(tmp_path):
    store = _mem_store(tmp_path, mem_writeback=True)
    store.save("ab12", "node", np.ones(64))
    assert store.has("ab12") and not store.has_local("ab12")
    store.delete("ab12")
    assert not store.has("ab12") and not store.mem_has("ab12")


# -- unified tier_status schema ----------------------------------------------

_RECORD_KEYS = {"name", "bytes", "budget", "entries", "leases",
                "hits", "misses"}


@pytest.mark.skipif(not HAVE_FLOCK, reason="fleet mode needs POSIX flock")
def test_tier_status_unified_schema(tmp_path):
    fs = FsObjectStore(str(tmp_path / "bucket"))
    store = _mem_store(tmp_path / "host", remote=RemoteStore(fs))
    store.save("ab12", "node", np.ones(256))
    store.writer_drain()
    store.load("ab12")                              # one memory hit
    status = store.tier_status()
    assert list(status) == ["memory", "local", "remote"]
    for tier in ("memory", "local", "remote"):
        rec = status[tier]
        assert rec is not None
        assert _RECORD_KEYS <= set(rec), f"{tier} missing unified keys"
        assert rec["name"] == tier
        assert set(rec["leases"]) == {"compute", "pins", "waiters"}
    assert status["memory"]["hits"] == 1
    assert status["memory"]["entries"] == 1
    assert status["memory"]["bytes"] > 0
    assert status["memory"]["budget"] == pytest.approx(64e6)
    assert status["local"]["entries"] == 1
    assert status["remote"]["entries"] == 1


def test_tier_status_memory_none_when_disabled(tmp_path):
    store = Store(str(tmp_path))
    assert store.tier_status()["memory"] is None


def test_server_status_includes_memory_tier(tmp_path):
    """SessionServer.status()['tiers'] carries the same unified memory
    record (servers default the tier on via StoreConfig)."""
    from repro.serve.server import SessionServer

    server = SessionServer(str(tmp_path / "srv"))
    try:
        tiers = server.status()["tiers"]
        assert tiers["memory"] is not None
        assert _RECORD_KEYS <= set(tiers["memory"])
        assert tiers["memory"]["budget"] == pytest.approx(256e6)
        assert server.status()["store_bytes"] == tiers["local"]["bytes"]
    finally:
        server.shutdown()


# -- per-tier pricing --------------------------------------------------------

def test_est_load_seconds_prices_cheapest_tier(tmp_path):
    store = _mem_store(tmp_path)
    store.save("ab12", "node", np.ones(1 << 16))    # resident in RAM
    nb = store.meta("ab12")["nbytes"]
    mem_est = store.est_load_seconds(nb, sig="ab12")
    disk_est = store.est_load_seconds(nb)           # no sig → durable tier
    assert mem_est < disk_est
    # a signature nowhere near RAM prices at the disk tier
    store._mem.drop("ab12")
    assert store.est_load_seconds(nb, sig="ab12") == disk_est


def test_device_array_offloads_to_host(tmp_path):
    """A jax device array admitted to the tier is offloaded to host RAM
    by the writer queue; the hit still serves a bit-identical value."""
    store = _mem_store(tmp_path)
    value = {"w": jnp.arange(2048, dtype=jnp.float32)}
    store.save("ab12", "node", value)
    store.writer_drain()                            # offload ran
    ent = store._mem.peek("ab12")
    assert ent is not None and not ent.has_device
    leaf = jax.tree_util.tree_leaves(ent.value)[0]
    assert isinstance(leaf, np.ndarray)
    got, _ = store.load("ab12")
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(2048, dtype=np.float32))
