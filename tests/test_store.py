"""Materialization store: roundtrips, resharding loads, management."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.store import Store, tree_nbytes


def test_roundtrip_pytree(tmp_path):
    store = Store(str(tmp_path))
    value = {"a": np.arange(10, dtype=np.float32),
             "b": [jnp.ones((3, 4), jnp.bfloat16), "hello"],
             "c": {"n": 42}}
    info = store.save("s1", "node", value)
    assert info.nbytes > 0 and store.has("s1")
    loaded, secs = store.load("s1")
    assert np.array_equal(loaded["a"], value["a"])
    assert loaded["b"][1] == "hello" and loaded["c"]["n"] == 42
    assert np.array_equal(np.asarray(loaded["b"][0]),
                          np.asarray(value["b"][0]))


def test_load_with_sharding(tmp_path):
    store = Store(str(tmp_path))
    arr = np.arange(64, dtype=np.float32).reshape(8, 8)
    store.save("s2", "arr", arr)
    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, PartitionSpec("data"))
    loaded, _ = store.load("s2", sharding_for_leaf=lambda i, shape, dt: sh)
    assert isinstance(loaded, jax.Array)
    assert loaded.sharding == sh
    assert np.array_equal(np.asarray(loaded), arr)


def test_delete_and_entries(tmp_path):
    store = Store(str(tmp_path))
    store.save("aa11", "x", np.zeros(4))
    store.save("bb22", "y", np.zeros(8))
    assert set(m["name"] for m in store.entries().values()) == {"x", "y"}
    freed = store.delete("aa11")
    assert freed > 0 and not store.has("aa11")
    assert store.total_bytes() == store.meta("bb22")["nbytes"]


def test_async_save(tmp_path):
    store = Store(str(tmp_path))
    th = store.save_async("cc33", "z", {"v": np.ones(100)})
    th.join()
    loaded, _ = store.load("cc33")
    assert np.array_equal(loaded["v"], np.ones(100))


def test_tree_nbytes():
    assert tree_nbytes({"x": np.zeros((10, 10), np.float32)}) == 400


def test_overwrite_same_sig(tmp_path):
    store = Store(str(tmp_path))
    store.save("dd44", "w", np.zeros(4))
    store.save("dd44", "w", np.ones(4))
    loaded, _ = store.load("dd44")
    assert np.array_equal(loaded, np.ones(4))
