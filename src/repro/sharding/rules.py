"""Logical-axis → mesh-axis rule sets (MaxText-style), per execution mode.

The resolver in ``models/params.py`` applies these with divisibility
fallback. Rule sets are the primary hillclimbing lever for the §Perf loop:
swapping ``embed: ("data",)`` (FSDP) for ``embed: ()`` (pure replication)
or moving MLP sharding changes the collective schedule without touching
model code.
"""
from __future__ import annotations

# Default: 2D-sharded params — FSDP over `data`, TP over `model`.
TRAIN_2D = {
    "vocab": ("model",),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": ("model",),
    "ssm_inner": ("model",),
}

# Pure tensor-parallel params (replicated over data) — small models where
# per-step all-gather of FSDP shards dominates.
TRAIN_TP_ONLY = dict(TRAIN_2D, embed=())

# Serving: params TP-sharded; no FSDP (no optimizer state at serve time).
SERVE = dict(TRAIN_2D, embed=())

# Pure FSDP / ZeRO-3: NO tensor parallelism — batch spreads over every mesh
# axis (see BATCH_AXES_BY_RULESET), params/optimizer stay 2D-sharded for
# storage and are all-gathered (bf16) around each use. Trades the fp32 TP
# activation all-reduce for bf16 weight gathers — wins when
# 3·params·2B < 2·B·S·D·4B per device (small models / big batches).
TRAIN_FSDP = {
    "vocab": ("model",),
    "embed": ("data", "model"),   # ZeRO-3 over all 256 chips: a 104B AdamW
    "heads": (),                  # state is 3.3 GB/device instead of 53 GB
    "kv_heads": (),
    "mlp": (),
    "experts": (),
    "expert_mlp": (),
    "ssm_inner": (),
}

RULESETS = {
    "train_2d": TRAIN_2D,
    "train_tp_only": TRAIN_TP_ONLY,
    "train_fsdp": TRAIN_FSDP,
    "serve": SERVE,
}

# Logical-batch physical axes per ruleset (default: data parallel only).
BATCH_AXES_BY_RULESET = {
    "train_fsdp": ("pod", "data", "model"),
}

