"""Activation sharding constraints that degrade gracefully.

``constrain(x, ("pod", "data"), None, "model")`` applies a
``with_sharding_constraint`` using only the mesh axes that actually exist in
the active mesh (so the same model code runs on a 1-CPU test mesh, a 256-chip
pod, or the 512-chip 2-pod mesh) and only when the named axis size divides
the corresponding dim.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec

BATCH_AXES = ("pod", "data")   # logical batch → physical axes (filtered)
SEQ_AXES = ("data",)           # sequence parallelism for long-context decode

_local = threading.local()


def batch_axes() -> tuple:
    """Physical axes the logical batch maps to (overridable per run —
    e.g. pure-FSDP spreads batch over (pod, data, model))."""
    return getattr(_local, "batch_axes", BATCH_AXES)


@contextlib.contextmanager
def use_batch_axes(axes: tuple):
    prev = getattr(_local, "batch_axes", BATCH_AXES)
    _local.batch_axes = tuple(axes)
    try:
        yield
    finally:
        _local.batch_axes = prev


def _active_mesh():
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - API drift guard
        return None
    if mesh.empty:
        return None
    return mesh


def constrain(x: jax.Array, *axes) -> jax.Array:
    mesh = _active_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = []
    for dim, a in zip(x.shape, axes):
        if a is None:
            entries.append(None)
            continue
        cand = a if isinstance(a, tuple) else (a,)
        cand = tuple(c for c in cand if c in sizes)
        total = 1
        for c in cand:
            total *= sizes[c]
        if cand and total > 1 and dim % total == 0:
            entries.append(cand if len(cand) > 1 else cand[0])
        else:
            entries.append(None)
    spec = PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
