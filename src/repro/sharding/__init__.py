from .activation import constrain, BATCH_AXES, SEQ_AXES
from . import rules

__all__ = ["constrain", "BATCH_AXES", "SEQ_AXES", "rules"]
