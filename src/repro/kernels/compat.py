"""jax-version compatibility shims shared by the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels were written against the new name, CI runners pin a jax that only
has the old one. Resolve whichever exists once, here.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
