"""Jit'd wrapper for the flash attention kernel.

Picks MXU-aligned block sizes, falls back to the jnp oracle when shapes
don't tile (tiny smoke shapes), and auto-selects interpret mode off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from ...models import layers as _layers  # GLOBAL_WINDOW sentinel


def _pick_block(s: int, target: int = 512) -> int:
    for cand in (target, 256, 128, 64, 32, 16, 8):
        if s % cand == 0 and cand <= s:
            return cand
    return 0


@functools.partial(jax.jit, static_argnames=("causal", "window", "force_ref"))
def flash_attention(q, k, v, q_offset=None, *, causal: bool = True,
                    window: int = 0, force_ref: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D); q_offset: () int32 or None.

    window: 0 or >= GLOBAL_WINDOW → global attention.
    """
    if window >= _layers.GLOBAL_WINDOW:
        window = 0
    if q_offset is None:
        q_offset = jnp.zeros((), jnp.int32)
    bq = _pick_block(q.shape[1])
    bk = _pick_block(k.shape[1])
    if force_ref or bq < 8 or bk < 8 or q.shape[-1] % 8:
        return ref.attention_ref(q, k, v, q_offset, causal=causal,
                                 window=window)
    interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(
        q, k, v, q_offset, causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret)
