"""Blockwise online-softmax attention (FlashAttention) for TPU via Pallas.

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
the innermost, *sequential* grid axis — the fp32 accumulator, running max
and running sum live in VMEM scratch across kv iterations. Q/K/V blocks are
(bq × head_dim) / (bk × head_dim) VMEM tiles (128-aligned for the MXU).

Supports: causal masking, sliding windows (per-call static window size),
GQA (q head h reads kv head h // group), and a traced per-call q position
offset (prefill continuation) via scalar prefetch.

Masked-out kv blocks are predicated away with ``pl.when`` — for causal
training that halves the work; for a 1024-window gemma3 layer the cost is
O(S·window) instead of O(S²).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(qoff_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, bq: int, bk: int, nkv: int,
            scale: float):
    ikv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qoff = qoff_ref[0]
    q_start = qoff + iq * bq
    k_start = ikv * bk
    # Block-level predication: skip kv blocks fully outside the mask.
    need = jnp.bool_(True)
    if causal:
        need &= k_start <= q_start + bq - 1
    if window > 0:
        need &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(need)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ikv == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           q_offset: jax.Array, *,
                           causal: bool, window: int,
                           bq: int, bk: int,
                           interpret: bool) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D); q_offset: () int32.

    window <= 0 means global. Returns (B, Sq, H, D) in q.dtype.
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nkv = sq // bq, sk // bk
    grid = (b, h, nq, nkv)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, nkv=nkv,
        scale=d ** -0.5)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, d),
                             lambda bi, hi, qi, ki, qoff: (bi, qi, hi, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda bi, hi, qi, ki, qoff: (bi, ki, hi // g, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda bi, hi, qi, ki, qoff: (bi, ki, hi // g, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, bq, 1, d), lambda bi, hi, qi, ki, qoff: (bi, qi, hi, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, d), jnp.float32),
                pltpu.VMEM((bq,), jnp.float32),
                pltpu.VMEM((bq,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(q_offset, jnp.int32).reshape(1), q, k, v)
