"""Pure-jnp oracle for flash attention (no blocking, fp32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_offset=0, *, causal: bool = True,
                  window: int = 0) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D). window <= 0 → global."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) * d ** -0.5
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    rel = q_pos - k_pos
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)
