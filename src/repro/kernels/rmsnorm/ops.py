"""Jit'd RMSNorm wrapper (flattens leading dims; falls back off-tile)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .rmsnorm import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, w, eps: float = 1e-5):
    shape = x.shape
    n = 1
    for s in shape[:-1]:
        n *= s
    x2 = x.reshape(n, shape[-1])
    br = next((b for b in (256, 128, 64, 32, 16, 8, 4, 2, 1) if n % b == 0))
    if br < 2 and n > 1:
        return ref.rmsnorm_ref(x, w, eps)
    interpret = jax.default_backend() != "tpu"
    out = rmsnorm_pallas(x2, w, eps=eps, block_rows=br, interpret=interpret)
    return out.reshape(shape)
