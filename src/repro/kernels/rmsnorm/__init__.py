from . import ops, ref
from .rmsnorm import rmsnorm_pallas

__all__ = ["ops", "ref", "rmsnorm_pallas"]
