"""Fused RMSNorm row kernel for TPU via Pallas.

Row-block tiles (br × D) in VMEM; fp32 mean-square reduction; one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float,
                   block_rows: int, interpret: bool) -> jax.Array:
    n, d = x.shape
    assert n % block_rows == 0
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)
