"""Jit'd SSD wrapper: Pallas intra-chunk kernel + XLA inter-chunk scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, a, B, C, *, chunk: int = 128, h0=None):
    """Full SSD with the quadratic part in Pallas.

    x: (b,S,H,P); dt: (b,S,H) fp32 (post-softplus); a: (H,) fp32 (negative);
    B, C: (b,S,N). Returns (y (b,S,H,P) fp32, h_final (b,H,P,N) fp32).
    """
    bsz, S, H, P = x.shape
    N = B.shape[-1]
    L = chunk
    S_orig = S
    if S % L:
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        S += pad
    nc = S // L

    da = (dt * a).reshape(bsz, nc, L, H)
    cs = jnp.cumsum(da, axis=2).reshape(bsz, S, H)       # within-chunk

    interpret = jax.default_backend() != "tpu"
    y_intra, states = ssd_chunk_pallas(
        x, dt, cs, B, C, chunk=L, interpret=interpret)

    # inter-chunk scan over boundary states
    seg = jnp.exp(cs.reshape(bsz, nc, L, H)[:, :, -1, :])  # (b,nc,H)
    # kernel returns states as (b,nc,H,N,P): transpose to (b,nc,H,P,N)
    states = jnp.swapaxes(states, -1, -2)

    def scan_fn(h, inp):
        s_c, g_c = inp
        return h * g_c[:, :, None, None] + s_c, h

    if h0 is None:
        h0 = jnp.zeros((bsz, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # (b,nc,H,P,N)

    # inter-chunk output: y_inter[t] = exp(cs_t) · C_t · h_prev(chunk(t))
    Cc = C.reshape(bsz, nc, L, N).astype(jnp.float32)
    y_inter = jnp.einsum("bcln,bchpn->bclhp", Cc, h_prev) \
        * jnp.exp(cs.reshape(bsz, nc, L, H))[..., None]
    y = y_intra + y_inter.reshape(bsz, S, H, P)
    return y[:, :S_orig], h_final
