"""Pure-jnp oracle for SSD: the naive sequential recurrence.

    h_t = h_{t-1} · exp(dt_t·a) + dt_t · (B_t ⊗ x_t)
    y_t = C_t · h_t

Deliberately independent of the chunked formulation so it validates both the
Pallas kernel and the XLA chunked reference in models/ssd.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, B, C, h0=None):
    """x: (b,S,H,P); dt: (b,S,H); a: (H,); B,C: (b,S,N).
    Returns (y (b,S,H,P) fp32, h_final (b,H,P,N) fp32)."""
    bsz, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp          # (b,H,P) (b,H) (b,N) (b,N)
        g = jnp.exp(dt_t * a)              # (b,H)
        upd = (dt_t[..., None] * x_t)[..., None] * B_t[:, None, None, :]
        h = h * g[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_final
