"""Mamba-2 SSD intra-chunk kernel for TPU via Pallas.

The SSD decomposition (DESIGN.md §6) makes the per-chunk work three
MXU matmuls; this kernel computes, for one (batch, chunk, head) grid cell
with VMEM tiles of chunk length L:

    y_intra = ((C Bᵀ) ∘ causal-decay ∘ dt) X              (L×L quadratic part)
    state   = Bᵀ (X ∘ dt ∘ decay-to-end)                  (chunk boundary state)

The cumulative log-decay ``cs = cumsum(dt·a)`` is precomputed outside (a
cheap elementwise pass) so the kernel body is pure matmul + exp — Mosaic
has no cumsum primitive.

The inter-chunk state scan (O(S/L) sequential) and the rank-1 inter-chunk
output correction stay in XLA (ops.py): they are bandwidth-trivial compared
to the quadratic part.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, dt_ref, cs_ref, b_ref, c_ref, y_ref, st_ref, *, L: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0]                             # (L,) f32
    cs = cs_ref[0, :, 0]                             # (L,) f32 cumulative
    B = b_ref[0, :, :].astype(jnp.float32)           # (L, N)
    C = c_ref[0, :, :].astype(jnp.float32)           # (L, N)

    # causal decay matrix: exp(cs_i - cs_j) for i >= j else 0
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    causal = ii >= jj
    diff = cs[:, None] - cs[None, :]
    decay = jnp.where(causal, jnp.exp(diff), 0.0)    # (L, L)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    w = cb * decay * dt[None, :]                     # weight for j→i
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # chunk state: Bᵀ (x ∘ dt ∘ decay-to-end)  → (N, P)
    seg_end = cs[L - 1]
    dte = dt * jnp.exp(seg_end - cs)                 # (L,)
    xd = x * dte[:, None]
    st = jax.lax.dot_general(B, xd, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    st_ref[0, 0, 0, :, :] = st


def ssd_chunk_pallas(x: jax.Array, dt: jax.Array, cs: jax.Array,
                     B: jax.Array, C: jax.Array, *, chunk: int,
                     interpret: bool) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD.

    x:  (batch, S, H, P)      dt, cs: (batch, S, H) fp32
    B, C: (batch, S, N)       S % chunk == 0
    Returns (y_intra (batch,S,H,P) fp32, states (batch, nc, H, N, P) fp32).
    """
    bsz, S, H, P = x.shape
    N = B.shape[-1]
    L = chunk
    assert S % L == 0
    nc = S // L
    grid = (bsz, nc, H)

    kernel = functools.partial(_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, L, 1), lambda bi, ci, hi: (bi, ci, hi)),
            pl.BlockSpec((1, L, 1), lambda bi, ci, hi: (bi, ci, hi)),
            pl.BlockSpec((1, L, N), lambda bi, ci, hi: (bi, ci, 0)),
            pl.BlockSpec((1, L, N), lambda bi, ci, hi: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, N, P),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, H, N, P), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, dt, cs, B, C)
