from . import ops, ref
from .ssd import ssd_chunk_pallas

__all__ = ["ops", "ref", "ssd_chunk_pallas"]
