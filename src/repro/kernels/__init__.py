# Pallas TPU kernels for the perf-critical substrate compute (the Helix
# paper itself has no kernel-level contribution — see DESIGN.md §6).
# Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with fallback), ref.py (pure-jnp oracle used by allclose tests).
