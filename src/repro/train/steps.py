"""Train / prefill / decode steps for every architecture family.

``train_step`` is the unit the launcher jits onto the mesh:

  * microbatched gradient accumulation via ``lax.scan`` (``cfg.grad_accum``)
    with fp32 accumulators — the psum/reduce-scatter that GSPMD inserts for
    the data axis sits *inside* the scan body, so XLA's latency-hiding
    scheduler overlaps gradient reduction with the next microbatch's compute;
  * global-norm clipping + AdamW (fp32 moments, sharded like params);
  * bf16 gradients on the wire (see optim/compress.py).

``prefill_step`` / ``decode_step`` are the serving units: prefill builds the
KV/SSM cache in one forward; decode advances one token against it.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models import encdec, lm, registry
from ..models.config import ArchConfig
from ..optim import adamw, schedules


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_train_state(cfg: ArchConfig, key: jax.Array) -> TrainState:
    params = registry.init(cfg, key)
    return TrainState(params=params, opt=adamw.init(params))


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def _xent(logits: jax.Array, targets: jax.Array, mask: jax.Array,
          impl: str = "gather") -> jax.Array:
    if impl == "onehot":
        # Vocab-sharding-friendly: both reductions contract the (sharded)
        # vocab axis with fused producers — no fp32 logits copy, no gather
        # across vocab shards (a psum appears instead).
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        shifted = (logits - m).astype(jnp.float32)
        logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(jnp.float32)
        oh = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("...v,...v->...", logits, oh).astype(jnp.float32)
        nll = (logz - gold) * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ArchConfig, params: Any, batch: dict) -> tuple[jax.Array, dict]:
    """batch keys: tokens (B,S) [+ frames / vision_embeds / mrope_positions /
    loss_mask]. Next-token LM loss (teacher-forced for enc-dec)."""
    tokens = batch["tokens"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.family == "audio":
        out = encdec.forward(cfg, params, batch["frames"], tokens)
    else:
        out = lm.forward(
            cfg, params, tokens,
            vision_embeds=batch.get("vision_embeds"),
            mrope_positions=batch.get("mrope_positions"))
    logits = out.logits[:, :-1]
    targets = tokens[:, 1:]
    loss = _xent(logits, targets, mask[:, 1:], impl=cfg.xent_impl)
    aux = 0.01 * out.aux_loss
    return loss + aux, {"loss": loss, "aux_loss": out.aux_loss}


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def train_step(cfg: ArchConfig, state: TrainState, batch: dict, *,
               peak_lr: float = 3e-4, warmup_steps: int = 100,
               total_steps: int = 10_000, clip_norm: float = 1.0
               ) -> tuple[TrainState, dict]:
    accum = max(cfg.grad_accum, 1)

    def split_micro(x):
        b = x.shape[0]
        return x.reshape((accum, b // accum) + x.shape[1:])

    grad_fn = jax.value_and_grad(
        lambda p, mb: loss_fn(cfg, p, mb), has_aux=True)

    if accum == 1:
        (_, metrics), grads = grad_fn(state.params, batch)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
    else:
        micro = {}
        for k, v in batch.items():
            if k == "mrope_positions":   # (3, B, S) → (accum, 3, B/a, S)
                micro[k] = jnp.moveaxis(
                    v.reshape(3, accum, -1, v.shape[-1]), 1, 0)
            else:
                micro[k] = split_micro(v)

        def body(carry, mb):
            acc, metric_acc = carry
            (_, metrics), grads = grad_fn(state.params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            metric_acc = jax.tree_util.tree_map(
                lambda a, m: a + m / accum, metric_acc, metrics)
            return (acc, metric_acc), None

        zero_grads = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        zero_metrics = {"loss": jnp.zeros((), jnp.float32),
                        "aux_loss": jnp.zeros((), jnp.float32)}
        (grads, metrics), _ = jax.lax.scan(
            body, (zero_grads, zero_metrics), micro, unroll=cfg.unroll)
        grads = jax.tree_util.tree_map(lambda g: g / accum, grads)

    grads, gnorm = adamw.clip_by_global_norm(grads, clip_norm)
    # schedule is 1-indexed: step 0 would otherwise get lr == 0
    lr = schedules.warmup_cosine(
        state.opt.step + 1, peak_lr=peak_lr, warmup_steps=warmup_steps,
        total_steps=total_steps)
    new_params, new_opt = adamw.update(state.params, grads, state.opt, lr=lr)
    metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                   step=new_opt.step.astype(jnp.float32))
    return TrainState(params=new_params, opt=new_opt), metrics


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def prefill_step(cfg: ArchConfig, params: Any, batch: dict, *,
                 max_len: int) -> tuple[jax.Array, Any]:
    """Build the cache from a full prompt. Returns (last logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    if cfg.family == "audio":
        enc_out = encdec.encode(cfg, params, batch["frames"])
        cache = encdec.init_cache(cfg, b, max_len,
                                  enc_len=enc_out.shape[1])
        out = encdec.decode(cfg, params, tokens, enc_out, cache=cache)
    else:
        cache = registry.init_cache(cfg, b, max_len)
        out = lm.forward(
            cfg, params, tokens, cache=cache,
            vision_embeds=batch.get("vision_embeds"),
            mrope_positions=batch.get("mrope_positions"))
    return out.logits[:, -1], out.cache


def decode_step(cfg: ArchConfig, params: Any, token: jax.Array,
                cache: Any) -> tuple[jax.Array, Any]:
    """One token against the cache. token: (B, 1). Returns (logits, cache)."""
    if cfg.family == "audio":
        out = encdec.decode(cfg, params, token, cache["enc_out"], cache=cache)
    else:
        out = lm.forward(cfg, params, token, cache=cache)
    return out.logits[:, 0], out.cache
