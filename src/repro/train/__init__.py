from .steps import TrainState, decode_step, init_train_state, loss_fn, \
    prefill_step, train_step

__all__ = ["TrainState", "decode_step", "init_train_state", "loss_fn",
           "prefill_step", "train_step"]
