"""granite-moe-1b-a400m — 32 experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from ..models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=MoECfg(num_experts=32, top_k=8, expert_d_ff=512),
    moe_impl="shard_map",
)
