"""gemma3-4b — dense GQA, 5:1 local:global sliding window, 128k ctx
[hf:google/gemma-3-1b-pt; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144, head_dim=256,
    window=1024, global_every=6,   # layers 5, 11, … are global
    grad_accum=2,
    window_cache=True,
)
