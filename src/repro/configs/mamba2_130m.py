"""mamba2-130m — attention-free SSM via state-space duality
[arXiv:2405.21060; unverified]."""
from ..models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=1, num_kv_heads=1,
    d_ff=0,                            # mamba blocks have no FFN
    vocab_size=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
    tie_embeddings=True,
)
