"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from ..models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    use_bias=True,
    moe=MoECfg(num_experts=60, top_k=4, expert_d_ff=1408,
               num_shared=4, shared_d_ff=5632),
    moe_impl="shard_map",
)
