"""qwen2-vl-7b — VLM backbone with M-RoPE; vision frontend stubbed
[arXiv:2409.12191; hf]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    use_bias=True,                     # qwen2 uses qkv bias
    mrope_sections=(16, 24, 24),       # t/h/w frequency pairs (sum = 64)
    grad_accum=2,
)
