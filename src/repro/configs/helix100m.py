"""helix100m — ~100M-param dense LM used by the end-to-end training example
(examples/train_lm.py) and integration tests. Not an assigned arch."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="helix100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=32768,
)
