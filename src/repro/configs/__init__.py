"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG: ArchConfig`` with the exact published
configuration; ``reduced(cfg)`` builds the same-family small config used by
CPU smoke tests.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ArchConfig, EncDecCfg, MoECfg, SSMCfg

from . import (command_r_plus_104b, gemma3_4b, granite_moe_1b_a400m,
               helix100m, internlm2_1_8b, jamba_v0_1_52b, mamba2_130m,
               qwen2_moe_a2_7b, qwen2_vl_7b, whisper_medium, yi_9b)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (internlm2_1_8b, yi_9b, command_r_plus_104b, gemma3_4b,
              jamba_v0_1_52b, qwen2_vl_7b, mamba2_130m,
              granite_moe_1b_a400m, qwen2_moe_a2_7b, whisper_medium,
              helix100m)
}

ASSIGNED = [n for n in ARCHS if n != "helix100m"]


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests: few layers, narrow
    width, tiny vocab, few experts — preserves every structural feature
    (GQA ratio, window pattern, MoE period, hybrid grouping, enc-dec)."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // cfg.num_heads),
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else 0,
    )
    if cfg.window is not None:
        kw["window"] = 8
        kw["global_every"] = 2   # [local, global] × 2 — exercises both paths
        kw["num_layers"] = 4
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (4, 6, 6)  # sums to head_dim//2 = 16
    if cfg.attn_every:
        kw["attn_every"] = 4
        kw["num_layers"] = 8
    if cfg.moe is not None:
        kw["moe"] = MoECfg(
            num_experts=min(8, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            expert_d_ff=64,
            num_shared=min(1, cfg.moe.num_shared),
            shared_d_ff=128 if cfg.moe.num_shared else 0,
            every_k_layers=cfg.moe.every_k_layers,
            # no token drops in smoke tests → decode == full forward exactly
            capacity_factor=float(min(8, cfg.moe.num_experts)))
    if cfg.ssm is not None:
        kw["ssm"] = SSMCfg(d_state=16, head_dim=16, expand=2, d_conv=4,
                           chunk=8)
    if cfg.encdec is not None:
        kw["encdec"] = EncDecCfg(enc_layers=2, dec_layers=2, cross_len=16)
    return dataclasses.replace(cfg, **kw)
