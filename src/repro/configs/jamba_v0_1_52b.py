"""jamba-v0.1-52b — hybrid Mamba+attention (1:7), MoE 16e top-2
[arXiv:2403.19887; hf]."""
from ..models.config import ArchConfig, MoECfg, SSMCfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    attn_every=8,                     # groups of [7×mamba, 1×attn]
    moe=MoECfg(num_experts=16, top_k=2, expert_d_ff=14336,
               every_k_layers=2),     # MoE FFN on every other layer
    ssm=SSMCfg(d_state=16, head_dim=64, expand=2, d_conv=4, chunk=128),
    grad_accum=4,
    moe_impl="shard_map",
)
