"""whisper-medium — enc-dec audio backbone; conv frontend stubbed
[arXiv:2212.04356; unverified]."""
from ..models.config import ArchConfig, EncDecCfg

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    use_bias=True,
    encdec=EncDecCfg(enc_layers=24, dec_layers=24, cross_len=1500),
)
