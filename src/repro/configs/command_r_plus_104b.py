"""command-r-plus-104b — dense GQA, no-bias, 256k vocab
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    use_bias=False,
    grad_accum=1,
    train_ruleset="train_fsdp",
)
