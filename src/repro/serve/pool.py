"""Process-wide elastic executor worker pool (ROADMAP "elastic sweep
execution").

Before the session server, every :class:`~repro.core.session.IterativeSession`
spawned its own ``max_workers`` threads per ``execute()`` call, so K
concurrent sessions × M workers oversubscribed the host with K·M runnable
threads. :class:`SharedWorkerPool` caps the *process-wide* total instead:

* every session's calling thread always runs one executor worker inline —
  a session can never be starved to zero workers, which also makes the
  scheme deadlock-free (no session ever blocks waiting for a pool slot);
* workers beyond that are *borrowed* from the pool non-blockingly, up to
  ``max_workers`` across all sessions at once. When the host is busy a
  session simply runs narrower; when it is quiet one session can use the
  whole pool. That is elastic execution: K sessions share M workers
  instead of pooling independently.

Fairness comes from the borrow granularity: slots are returned when an
``execute()`` call finishes, so long-running sessions cannot hold the pool
across iterations, and the inline-worker floor guarantees progress for
every session regardless of who currently holds the slots.
"""
from __future__ import annotations

import threading
from typing import Callable


class SharedWorkerPool:
    """Bounded pool of executor worker slots shared by all sessions.

    ``run(fn, want)`` runs ``fn`` (an executor worker loop) on the calling
    thread and on up to ``want - 1`` borrowed threads, returning when all
    of them have finished. Borrowing is non-blocking: if the pool is
    saturated the call proceeds with fewer workers rather than waiting.
    """

    def __init__(self, max_workers: int):
        self.max_workers = max(1, int(max_workers))
        self._lock = threading.Lock()
        self.in_use = 0          # borrowed slots right now
        self.peak_in_use = 0     # high-water mark (observability/tests)

    def _try_borrow(self) -> bool:
        with self._lock:
            if self.in_use >= self.max_workers:
                return False
            self.in_use += 1
            self.peak_in_use = max(self.peak_in_use, self.in_use)
            return True

    def _return_slot(self) -> None:
        with self._lock:
            self.in_use -= 1

    def run(self, fn: Callable[[], None], want: int) -> int:
        """Run ``fn`` inline plus on up to ``want - 1`` borrowed workers.

        Returns the number of workers that actually ran (≥ 1). Exceptions
        from the inline worker propagate; borrowed workers run the same
        executor loop, which routes its failures through the executor's
        own error channel.
        """
        threads: list[threading.Thread] = []
        for _ in range(max(0, int(want) - 1)):
            if not self._try_borrow():
                break

            def slot() -> None:
                try:
                    fn()
                finally:
                    self._return_slot()

            t = threading.Thread(target=slot, name="helix-pool-worker",
                                 daemon=True)
            try:
                t.start()
            except RuntimeError:      # thread exhaustion: give the slot
                self._return_slot()   # back instead of leaking capacity
                break
            threads.append(t)
        try:
            fn()   # the caller always contributes one worker
        finally:
            for t in threads:
                t.join()
        return 1 + len(threads)

    def stats(self) -> dict:
        """Current pool occupancy (JSON-safe, for server status RPC)."""
        with self._lock:
            return {"max_workers": self.max_workers,
                    "in_use": self.in_use,
                    "peak_in_use": self.peak_in_use}
