"""Globally shared-prefix-aware scheduling (Li et al. 2019 applied fleet-
wide).

PR 2's sweep relied on *lease contention* for cross-session coordination:
all K variants start at once, and siblings that need an in-flight shared
signature block on its compute lease — correct (each shared signature is
computed exactly once) but wasteful, because a blocked sibling occupies a
session slot doing nothing. The session server knows every live
submission's signature set, so it can do better than contention:

* **Multiplicity map** — for every signature, how many live (queued or
  running) submissions need it. This is the observed analogue of the
  sweep pre-pass's shared-signature set, maintained incrementally as
  clients come and go, and it doubles as OMP's amortization input
  (``Materializer.multiplicity``).
* **Shared-prefix-first order** — among dispatchable submissions, run the
  one whose *not-yet-materialized* signatures carry the largest shared
  weight (multiplicity − 1, scaled by the cost model's estimated compute
  seconds). Expensive widely-shared prefixes start as early as possible,
  so they are already hot when sibling workflows reach the front.
* **Sibling deferral** — a submission whose needed signatures are being
  computed by a running submission is outranked by *independent* queued
  work: the independent job gets the slot (it makes full-speed progress
  where the sibling would intermittently block on compute leases).
  Deferral reorders but never idles: when only blocked submissions are
  queued, the one with the *smallest cost-weighted overlap* with
  in-flight work is dispatched anyway — it lease-follows the leader the
  shortest time before diverging into independent compute (prefer a
  different model family over the running arm's twin), which is strictly
  better than an empty slot. The lease protocol underneath remains the
  correctness backstop; the scheduler only spends slots where they buy
  wall-clock.

The scheduler is pure policy: it owns no locks and mutates nothing but
its multiplicity map. The server drives it under the server lock.

Multi-tenant fairness (``schedule="fair"``) layers
:class:`TenantScheduler` *on top of* this order: a weighted-fair pass
picks which tenant's turn it is (deficit/virtual-time round-robin over
served compute seconds), then :class:`PrefixScheduler` picks
shared-prefix-first *within* that tenant's queue. Cross-tenant fairness
and intra-tenant reuse compose instead of competing.
"""
from __future__ import annotations

from typing import Iterable, Mapping, Protocol, Sequence


class _SchedJob(Protocol):
    """What the scheduler needs to know about a submission."""

    seq: int                    # arrival order (FIFO tiebreak)
    sigs: frozenset             # the submission's full signature set
    priority: int               # dispatch class (higher first; default 0)


class PrefixScheduler:
    """Shared-prefix-first dispatch order over live submissions."""

    def __init__(self, store, cost_model, mode: str = "prefix"):
        if mode not in ("prefix", "fifo"):
            raise ValueError(f"unknown schedule mode: {mode!r}")
        self.store = store
        self.cost_model = cost_model
        self.mode = mode
        self._mult: dict[str, int] = {}

    # -- multiplicity map --------------------------------------------------
    def add(self, job: _SchedJob) -> None:
        """Track a newly submitted job's signatures."""
        for sig in job.sigs:
            self._mult[sig] = self._mult.get(sig, 0) + 1

    def remove(self, job: _SchedJob) -> None:
        """Drop a finished job's signatures from the live map."""
        for sig in job.sigs:
            cur = self._mult.get(sig, 0) - 1
            if cur <= 0:
                self._mult.pop(sig, None)
            else:
                self._mult[sig] = cur

    def multiplicity(self, sig: str) -> int:
        """Live submissions (queued or running) that need ``sig``."""
        return self._mult.get(sig, 0)

    def is_live(self, sig: str) -> bool:
        """Eviction veto: does any live (queued or running) submission
        still plan to use ``sig``? The server hands this to the fleet
        evictor so entries live clients want are never candidates —
        evicting them would force the exact recompute the store exists
        to avoid."""
        return self._mult.get(str(sig), 0) > 0

    # -- dispatch policy ---------------------------------------------------
    def shared_weight(self, job: _SchedJob, has=None) -> float:
        """Cost-weighted shared work this job would *newly* compute.

        Sums ``(multiplicity - 1) · est_compute_seconds`` over the job's
        signatures that are shared with other live submissions and not in
        the store yet. Jobs whose shared prefix is already materialized
        score 0 (they are cheap loads and can run any time). ``has``
        optionally overrides ``store.has`` (pick() passes a memo so one
        dispatch decision stats each signature at most once).
        """
        has = has or self.store.has
        total = 0.0
        for sig in job.sigs:
            m = self._mult.get(sig, 0)
            if m >= 2 and not has(sig):
                total += (m - 1) * self.cost_model.compute_cost(sig)
        return total

    def blocked(self, job: _SchedJob, inflight: Iterable[str],
                has=None) -> bool:
        """Would dispatching ``job`` now just block on a compute lease?

        True iff a signature the job needs is assigned to a running
        submission and has not been materialized yet.
        """
        has = has or self.store.has
        for sig in inflight:
            if sig in job.sigs and not has(sig):
                return True
        return False

    def overlap_weight(self, job: _SchedJob, inflight: set,
                       has=None) -> float:
        """Cost-weighted overlap between ``job`` and in-flight work.

        Estimated compute seconds of the job's signatures a running
        submission is (presumably) about to produce. Among blocked jobs
        the scheduler dispatches the one with the *smallest* overlap: it
        spends the least time lease-following before diverging into
        independent compute — e.g. prefer the arm from a different model
        family over the running arm's twin.
        """
        has = has or self.store.has
        return sum(self.cost_model.compute_cost(sig)
                   for sig in job.sigs
                   if sig in inflight and not has(sig))

    def pick(self, queued: Sequence[_SchedJob],
             inflight: Iterable[str]) -> _SchedJob | None:
        """Choose the next submission to dispatch (None iff queue empty).

        ``queued`` is the live queue in arrival order; ``inflight`` is the
        union of running submissions' signatures. Within a priority class
        (``priority`` descending — the search driver marks promoted rungs
        so survivors outrank fresh exploratory arms), unblocked
        submissions are ranked by shared weight (descending) then
        arrival; blocked ones (they would lease-wait on a running
        sibling) are considered only when no unblocked submission exists
        — a lease-following sibling still beats an idle slot.
        """
        if not queued:
            return None
        if self.mode == "fifo":
            # Priority classes apply in fifo mode too (arrival order
            # within a class); all-default-priority queues reduce to
            # queued[0], so the PR 2 baseline is byte-identical.
            return min(queued,
                       key=lambda j: (-getattr(j, "priority", 0), j.seq))
        inflight = set(inflight)
        # One store stat per signature per decision: queued siblings
        # largely share signatures, and this may run under the server
        # lock on a slow filesystem.
        memo: dict[str, bool] = {}

        def has(sig: str) -> bool:
            v = memo.get(sig)
            if v is None:
                v = memo[sig] = self.store.has(sig)
            return v

        best: _SchedJob | None = None
        best_key: tuple | None = None
        for job in queued:
            is_blocked = self.blocked(job, inflight, has)
            key = (is_blocked, -getattr(job, "priority", 0),
                   self.overlap_weight(job, inflight, has)
                   if is_blocked else 0.0,
                   -self.shared_weight(job, has), job.seq)
            if best_key is None or key < best_key:
                best, best_key = job, key
        return best


class TenantScheduler:
    """Weighted fair share across tenants, prefix-first within each.

    A virtual-time variant of deficit round robin: every tenant carries
    a meter of compute seconds served (plus a provisional charge for its
    jobs currently in flight), and each dispatch goes to the backlogged
    tenant with the smallest ``meter / weight`` — so over any interval
    in which a set of tenants stays backlogged, their served
    compute-seconds converge to the ratio of their weights, and a
    zero-backlog tenant's unused share redistributes instead of idling
    a slot (work-conserving). *Within* the chosen tenant's queue the
    wrapped :class:`PrefixScheduler` keeps the shared-prefix-first
    order, so fairness costs none of the reuse scheduling.

    Charging protocol (driven by the server under its lock):
    ``note_dispatch(job)`` adds a provisional estimate when a job leaves
    the queue — without it, K concurrent slots could all go to the
    lowest-meter tenant before any job finishes — and
    ``note_finish(job, seconds)`` replaces the estimate with the
    measured compute seconds. The provisional estimate is an EWMA of
    completed job durations (tenant-agnostic; it only needs to be the
    same order of magnitude as real jobs to keep concurrent dispatch
    honest).

    The multiplicity surface (``add`` / ``remove`` / ``multiplicity`` /
    ``is_live``) delegates to the wrapped scheduler unchanged: OMP
    amortization and eviction vetoes stay fleet-wide — reuse across
    tenants is the point of sharing the substrate; only *dispatch* is
    divided fairly.
    """

    mode = "fair"

    def __init__(self, inner: PrefixScheduler,
                 weights: Mapping[str, float] | None = None):
        """Wrap ``inner``; ``weights`` maps tenant id → fair-share
        weight (missing tenants use the ``"*"`` entry, then 1.0)."""
        self.inner = inner
        self.store = inner.store
        self.cost_model = inner.cost_model
        self.weights = dict(weights or {})
        self._served: dict[str, float] = {}
        self._inflight: dict[int, tuple[str, float]] = {}
        self._avg_s = 1.0        # EWMA of measured job compute seconds
        self._n_done = 0

    # -- multiplicity surface (delegated; fleet-wide on purpose) -----------
    def add(self, job) -> None:
        """Track a newly submitted job's signatures (fleet-wide map)."""
        self.inner.add(job)

    def remove(self, job) -> None:
        """Drop a finished job's signatures from the live map."""
        self.inner.remove(job)

    def multiplicity(self, sig: str) -> int:
        """Live submissions that need ``sig`` — across all tenants."""
        return self.inner.multiplicity(sig)

    def is_live(self, sig: str) -> bool:
        """Eviction veto, tenant-agnostic: any live submission counts."""
        return self.inner.is_live(sig)

    # -- fair-share accounting ---------------------------------------------
    def weight_of(self, tenant: str) -> float:
        """Fair-share weight for ``tenant`` (``"*"`` default, else 1)."""
        w = self.weights.get(tenant)
        if w is None:
            w = self.weights.get("*", 1.0)
        return max(float(w), 1e-9)

    def virtual_time(self, tenant: str) -> float:
        """``(served + provisional in-flight) / weight`` — the fair
        queueing clock this scheduler equalizes across tenants."""
        meter = self._served.get(tenant, 0.0)
        meter += sum(est for t, est in self._inflight.values()
                     if t == tenant)
        return meter / self.weight_of(tenant)

    def served_seconds(self, tenant: str) -> float:
        """Measured compute seconds served to ``tenant`` so far."""
        return self._served.get(tenant, 0.0)

    def note_dispatch(self, job, est_s: float | None = None) -> None:
        """Charge a provisional estimate while ``job`` runs."""
        tenant = getattr(job, "tenant", "default")
        est = float(est_s) if est_s and est_s > 0 else self._avg_s
        self._inflight[job.id] = (tenant, est)

    def note_finish(self, job, seconds: float) -> None:
        """Replace ``job``'s provisional charge with measured seconds."""
        ent = self._inflight.pop(job.id, None)
        tenant = ent[0] if ent else getattr(job, "tenant", "default")
        seconds = max(float(seconds), 0.0)
        self._served[tenant] = self._served.get(tenant, 0.0) + seconds
        if seconds > 0:
            self._n_done += 1
            alpha = 0.3 if self._n_done > 3 else 1.0 / self._n_done
            self._avg_s += alpha * (seconds - self._avg_s)

    def snapshot(self) -> dict:
        """Per-tenant fairness state for ``status()`` (JSON-safe)."""
        tenants = set(self._served) | {t for t, _ in
                                       self._inflight.values()}
        return {t: {"served_s": self._served.get(t, 0.0),
                    "weight": self.weight_of(t),
                    "virtual_time": self.virtual_time(t)}
                for t in sorted(tenants)}

    # -- dispatch policy ---------------------------------------------------
    def pick(self, queued: Sequence, inflight: Iterable[str]):
        """Pick the lowest-virtual-time backlogged tenant's best job.

        Ties break by tenant id so replays are deterministic. Returns
        None iff ``queued`` is empty.
        """
        if not queued:
            return None
        by_tenant: dict[str, list] = {}
        for job in queued:
            by_tenant.setdefault(getattr(job, "tenant", "default"),
                                 []).append(job)
        tenant = min(by_tenant, key=lambda t: (self.virtual_time(t), t))
        return self.inner.pick(by_tenant[tenant], inflight)
