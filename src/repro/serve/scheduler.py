"""Globally shared-prefix-aware scheduling (Li et al. 2019 applied fleet-
wide).

PR 2's sweep relied on *lease contention* for cross-session coordination:
all K variants start at once, and siblings that need an in-flight shared
signature block on its compute lease — correct (each shared signature is
computed exactly once) but wasteful, because a blocked sibling occupies a
session slot doing nothing. The session server knows every live
submission's signature set, so it can do better than contention:

* **Multiplicity map** — for every signature, how many live (queued or
  running) submissions need it. This is the observed analogue of the
  sweep pre-pass's shared-signature set, maintained incrementally as
  clients come and go, and it doubles as OMP's amortization input
  (``Materializer.multiplicity``).
* **Shared-prefix-first order** — among dispatchable submissions, run the
  one whose *not-yet-materialized* signatures carry the largest shared
  weight (multiplicity − 1, scaled by the cost model's estimated compute
  seconds). Expensive widely-shared prefixes start as early as possible,
  so they are already hot when sibling workflows reach the front.
* **Sibling deferral** — a submission whose needed signatures are being
  computed by a running submission is outranked by *independent* queued
  work: the independent job gets the slot (it makes full-speed progress
  where the sibling would intermittently block on compute leases).
  Deferral reorders but never idles: when only blocked submissions are
  queued, the one with the *smallest cost-weighted overlap* with
  in-flight work is dispatched anyway — it lease-follows the leader the
  shortest time before diverging into independent compute (prefer a
  different model family over the running arm's twin), which is strictly
  better than an empty slot. The lease protocol underneath remains the
  correctness backstop; the scheduler only spends slots where they buy
  wall-clock.

The scheduler is pure policy: it owns no locks and mutates nothing but
its multiplicity map. The server drives it under the server lock.
"""
from __future__ import annotations

from typing import Iterable, Protocol, Sequence


class _SchedJob(Protocol):
    """What the scheduler needs to know about a submission."""

    seq: int                    # arrival order (FIFO tiebreak)
    sigs: frozenset             # the submission's full signature set
    priority: int               # dispatch class (higher first; default 0)


class PrefixScheduler:
    """Shared-prefix-first dispatch order over live submissions."""

    def __init__(self, store, cost_model, mode: str = "prefix"):
        if mode not in ("prefix", "fifo"):
            raise ValueError(f"unknown schedule mode: {mode!r}")
        self.store = store
        self.cost_model = cost_model
        self.mode = mode
        self._mult: dict[str, int] = {}

    # -- multiplicity map --------------------------------------------------
    def add(self, job: _SchedJob) -> None:
        """Track a newly submitted job's signatures."""
        for sig in job.sigs:
            self._mult[sig] = self._mult.get(sig, 0) + 1

    def remove(self, job: _SchedJob) -> None:
        """Drop a finished job's signatures from the live map."""
        for sig in job.sigs:
            cur = self._mult.get(sig, 0) - 1
            if cur <= 0:
                self._mult.pop(sig, None)
            else:
                self._mult[sig] = cur

    def multiplicity(self, sig: str) -> int:
        """Live submissions (queued or running) that need ``sig``."""
        return self._mult.get(sig, 0)

    def is_live(self, sig: str) -> bool:
        """Eviction veto: does any live (queued or running) submission
        still plan to use ``sig``? The server hands this to the fleet
        evictor so entries live clients want are never candidates —
        evicting them would force the exact recompute the store exists
        to avoid."""
        return self._mult.get(str(sig), 0) > 0

    # -- dispatch policy ---------------------------------------------------
    def shared_weight(self, job: _SchedJob, has=None) -> float:
        """Cost-weighted shared work this job would *newly* compute.

        Sums ``(multiplicity - 1) · est_compute_seconds`` over the job's
        signatures that are shared with other live submissions and not in
        the store yet. Jobs whose shared prefix is already materialized
        score 0 (they are cheap loads and can run any time). ``has``
        optionally overrides ``store.has`` (pick() passes a memo so one
        dispatch decision stats each signature at most once).
        """
        has = has or self.store.has
        total = 0.0
        for sig in job.sigs:
            m = self._mult.get(sig, 0)
            if m >= 2 and not has(sig):
                total += (m - 1) * self.cost_model.compute_cost(sig)
        return total

    def blocked(self, job: _SchedJob, inflight: Iterable[str],
                has=None) -> bool:
        """Would dispatching ``job`` now just block on a compute lease?

        True iff a signature the job needs is assigned to a running
        submission and has not been materialized yet.
        """
        has = has or self.store.has
        for sig in inflight:
            if sig in job.sigs and not has(sig):
                return True
        return False

    def overlap_weight(self, job: _SchedJob, inflight: set,
                       has=None) -> float:
        """Cost-weighted overlap between ``job`` and in-flight work.

        Estimated compute seconds of the job's signatures a running
        submission is (presumably) about to produce. Among blocked jobs
        the scheduler dispatches the one with the *smallest* overlap: it
        spends the least time lease-following before diverging into
        independent compute — e.g. prefer the arm from a different model
        family over the running arm's twin.
        """
        has = has or self.store.has
        return sum(self.cost_model.compute_cost(sig)
                   for sig in job.sigs
                   if sig in inflight and not has(sig))

    def pick(self, queued: Sequence[_SchedJob],
             inflight: Iterable[str]) -> _SchedJob | None:
        """Choose the next submission to dispatch (None iff queue empty).

        ``queued`` is the live queue in arrival order; ``inflight`` is the
        union of running submissions' signatures. Within a priority class
        (``priority`` descending — the search driver marks promoted rungs
        so survivors outrank fresh exploratory arms), unblocked
        submissions are ranked by shared weight (descending) then
        arrival; blocked ones (they would lease-wait on a running
        sibling) are considered only when no unblocked submission exists
        — a lease-following sibling still beats an idle slot.
        """
        if not queued:
            return None
        if self.mode == "fifo":
            # Priority classes apply in fifo mode too (arrival order
            # within a class); all-default-priority queues reduce to
            # queued[0], so the PR 2 baseline is byte-identical.
            return min(queued,
                       key=lambda j: (-getattr(j, "priority", 0), j.seq))
        inflight = set(inflight)
        # One store stat per signature per decision: queued siblings
        # largely share signatures, and this may run under the server
        # lock on a slow filesystem.
        memo: dict[str, bool] = {}

        def has(sig: str) -> bool:
            v = memo.get(sig)
            if v is None:
                v = memo[sig] = self.store.has(sig)
            return v

        best: _SchedJob | None = None
        best_key: tuple | None = None
        for job in queued:
            is_blocked = self.blocked(job, inflight, has)
            key = (is_blocked, -getattr(job, "priority", 0),
                   self.overlap_weight(job, inflight, has)
                   if is_blocked else 0.0,
                   -self.shared_weight(job, has), job.seq)
            if best_key is None or key < best_key:
                best, best_key = job, key
        return best
