"""The session-server layer: one long-running process per host multiplexes
many clients' workflow submissions onto one shared store, schedules them
with global knowledge (shared-prefix-first, live signature multiplicity
feeding OMP's amortization), and shares one elastic executor worker pool
across all hosted sessions. Multi-tenancy (tenancy.py) adds per-tenant
quotas, fair-share dispatch, and workflow allowlists; the fleet router
(router.py) shards N servers behind one Client with consistent-hash
prefix routing. See docs/architecture.md for the layer map."""
from .client import (Client, InProcessClient, ServerClient, ServerError,
                     connect, connect_tcp, connect_unix)
from .pool import SharedWorkerPool
from .protocol import (ProtocolError, QuotaExceeded, ServerBusy, jsonable,
                       recv_msg, send_msg)
from .router import FleetRouter, rendezvous
from .scheduler import PrefixScheduler, TenantScheduler
from .server import Job, SessionServer, SharedNonces
from .tenancy import ScopedLedger, TenantQuota, TenantSpec, validate_params

__all__ = [
    "Client", "InProcessClient", "ServerClient", "ServerError",
    "connect", "connect_tcp", "connect_unix",
    "SharedWorkerPool",
    "ProtocolError", "QuotaExceeded", "ServerBusy", "jsonable",
    "recv_msg", "send_msg",
    "PrefixScheduler", "TenantScheduler",
    "Job", "SessionServer", "SharedNonces",
    "FleetRouter", "rendezvous",
    "ScopedLedger", "TenantQuota", "TenantSpec", "validate_params",
]
