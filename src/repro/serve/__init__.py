"""The session-server layer: one long-running process per host multiplexes
many clients' workflow submissions onto one shared store, schedules them
with global knowledge (shared-prefix-first, live signature multiplicity
feeding OMP's amortization), and shares one elastic executor worker pool
across all hosted sessions. See docs/architecture.md for the layer map."""
from .client import (Client, InProcessClient, ServerClient, ServerError,
                     connect, connect_tcp, connect_unix)
from .pool import SharedWorkerPool
from .protocol import (ProtocolError, ServerBusy, jsonable, recv_msg,
                       send_msg)
from .scheduler import PrefixScheduler
from .server import Job, SessionServer, SharedNonces

__all__ = [
    "Client", "InProcessClient", "ServerClient", "ServerError",
    "connect", "connect_tcp", "connect_unix",
    "SharedWorkerPool",
    "ProtocolError", "ServerBusy", "jsonable", "recv_msg", "send_msg",
    "PrefixScheduler",
    "Job", "SessionServer", "SharedNonces",
]
