"""Clients for the session server: socket-based and in-process.

:class:`ServerClient` speaks the length-prefixed JSON protocol over a unix
or TCP socket (see protocol.py); :class:`InProcessClient` drives a
:class:`~repro.serve.server.SessionServer` in the same process through the
identical message handler, so tests exercise the real protocol semantics
without a socket. Both expose the same methods and return the same
JSON-shaped dicts.

Quickstart::

    from repro.serve import SessionServer, connect_unix

    server = SessionServer("/data/helix", registry={"census": build})
    path = server.serve_unix("/tmp/helix.sock")

    client = connect_unix(path)
    job = client.submit("census", {"reg": 0.3})
    print(client.wait(job)["outputs"])
    client.close()
"""
from __future__ import annotations

import socket
from typing import Any, Mapping

from .protocol import recv_msg, send_msg
from .server import SessionServer


class ServerError(RuntimeError):
    """The server answered ``ok: false``; the message is its ``error``."""


class _ClientBase:
    """Shared convenience methods over the raw ``op`` messages."""

    def _rpc(self, **msg: Any) -> dict:
        raise NotImplementedError

    def hello(self) -> dict:
        """Server identity, schedule mode, and registered workflows."""
        return self._rpc(op="hello")

    def submit(self, workflow: str, params: Mapping[str, Any]
               | None = None, name: str | None = None) -> str:
        """Submit a registered workflow by name; returns the job id."""
        resp = self._rpc(op="submit", workflow=workflow,
                         params=dict(params or {}), name=name)
        return resp["job"]

    def wait(self, job: str, timeout: float | None = None) -> dict:
        """Block until ``job`` finishes; returns its summary dict."""
        return self._rpc(op="wait", job=job, timeout=timeout)

    def job(self, job: str) -> dict:
        """Non-blocking job summary."""
        return self._rpc(op="job", job=job)

    def forget(self, job: str) -> bool:
        """Release a finished job's server-side record (frees its
        outputs); False when unknown or still running."""
        return bool(self._rpc(op="forget", job=job)["forgotten"])

    def status(self) -> dict:
        """Server status snapshot (queue depth, slots, pool, store)."""
        return self._rpc(op="status")

    def multiplicity(self, sig: str) -> int:
        """Live cross-client multiplicity of one signature."""
        return int(self._rpc(op="multiplicity", sig=sig)["multiplicity"])

    def drain(self, timeout: float | None = None) -> bool:
        """Ask the server to stop accepting and finish live work."""
        return bool(self._rpc(op="drain", timeout=timeout)["drained"])

    def shutdown(self) -> dict:
        """Request server shutdown (graceful: submitted work finishes)."""
        return self._rpc(op="shutdown")


class ServerClient(_ClientBase):
    """Synchronous socket client: one request/response per call.

    One instance wraps one connection and is not thread-safe; concurrent
    clients each open their own (``submit`` returns immediately, so a
    single client can still keep many jobs in flight and ``wait`` on them
    in turn).
    """

    def __init__(self, sock: socket.socket):
        self._sock = sock

    def _rpc(self, **msg: Any) -> dict:
        send_msg(self._sock, msg)
        resp = recv_msg(self._sock)
        if resp is None:
            raise ConnectionError("server closed the connection")
        if not resp.get("ok"):
            raise ServerError(resp.get("error", "unknown server error"))
        return resp

    def close(self) -> None:
        """Close the connection (idempotent)."""
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class InProcessClient(_ClientBase):
    """Protocol-faithful client for a server in the same process.

    Routes every call through ``SessionServer._handle`` — the same code
    path socket connections hit — so responses are byte-for-byte what the
    wire would carry, minus the framing. ``shutdown`` additionally joins
    the server (sockets get that for free from the connection handler).
    """

    def __init__(self, server: SessionServer):
        self._server = server

    def _rpc(self, **msg: Any) -> dict:
        resp = self._server._handle(msg)
        if not resp.get("ok"):
            raise ServerError(resp.get("error", "unknown server error"))
        return resp

    def shutdown(self) -> dict:
        """Request shutdown and join the server before returning."""
        resp = super().shutdown()
        self._server.shutdown()
        return resp

    def close(self) -> None:
        """No-op (kept for interface parity with ServerClient)."""


def connect_unix(path: str) -> ServerClient:
    """Connect to a session server's unix domain socket."""
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(path)
    return ServerClient(sock)


def connect_tcp(host: str, port: int) -> ServerClient:
    """Connect to a session server's TCP endpoint."""
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.connect((host, port))
    return ServerClient(sock)
