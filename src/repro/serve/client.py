"""Clients for the session server: socket-based and in-process.

:class:`ServerClient` speaks the length-prefixed JSON protocol over a unix
or TCP socket (see protocol.py); :class:`InProcessClient` drives a
:class:`~repro.serve.server.SessionServer` in the same process through the
identical message handler, so tests exercise the real protocol semantics
without a socket. Both expose the same methods and return the same
JSON-shaped dicts.

Hardening (both clients):

* **Backpressure** — a ``busy`` submit response (bounded admission queue
  full) is retried automatically after the server's ``retry_after`` hint,
  up to ``busy_retries`` times; then :class:`ServerBusy` propagates.
* **Bounded RPCs** — ``timeout`` on the connect helpers puts a socket
  timeout on every send/recv, so a dead or wedged server can never hang a
  client forever. Long ``wait`` calls are transparently *chunked* into
  RPCs shorter than the socket timeout (a slow job is not a dead server).
* **Reconnect** — after any socket error the connection is considered
  poisoned (a late response would desynchronize the framing); when a
  reconnect factory is available (the connect helpers install one) the
  client dials a fresh connection and — only when the request provably
  never reached the server, or the op is idempotent — retries it once.
  A ``submit`` that may have been received is never resent (no double
  submissions); the error propagates instead.

Quickstart (the one entry point — :func:`connect` — picks the transport
from what you hand it)::

    from repro.serve import SessionServer, connect

    server = SessionServer("/data/helix", registry={"census": build})
    path = server.serve_unix("/tmp/helix.sock")

    client = connect(path, timeout=30.0)   # or connect(server),
    job = client.submit("census", {"reg": 0.3})  # or connect((host, port))
    print(client.wait(job)["outputs"])
    client.close()

Direct construction of :class:`ServerClient` / :class:`InProcessClient`
(and the transport-specific ``connect_unix`` / ``connect_tcp`` helpers)
still works but is discouraged in new code: everything that consumes a
client — the search driver above all — is written against the
:class:`Client` protocol and should receive whatever :func:`connect`
returns.
"""
from __future__ import annotations

import socket
import time
from typing import Any, Callable, Mapping, Protocol, runtime_checkable

from .protocol import QuotaExceeded, ServerBusy, recv_msg, send_msg
from .server import SessionServer


@runtime_checkable
class Client(Protocol):
    """What every session-server client speaks, transport aside.

    The structural type returned by :func:`connect` and consumed by the
    search driver and examples: JSON-shaped dicts in and out, identical
    over a unix socket, TCP, or an in-process server. See
    :class:`_ClientBase` for the shared method semantics.
    """

    def hello(self) -> dict: ...            # noqa: D102 — protocol stubs;
    def submit(self, workflow: str,         # noqa: D102 — semantics live
               params: Mapping[str, Any] | None = None,     # on _ClientBase
               name: str | None = None, timeout: float | None = None,
               priority: int = 0) -> str: ...
    def estimate(self, workflow: str,  # noqa: D102
                 params: Mapping[str, Any] | None = None) -> dict: ...
    def wait(self, job: str, timeout: float | None = None,  # noqa: D102
             detail: bool = False) -> dict: ...
    def job(self, job: str, detail: bool = False) -> dict: ...  # noqa: D102
    def cancel(self, job: str) -> bool: ...                 # noqa: D102
    def forget(self, job: str) -> bool: ...                 # noqa: D102
    def status(self) -> dict: ...                           # noqa: D102
    def multiplicity(self, sig: str) -> int: ...            # noqa: D102
    def drain(self, timeout: float | None = None) -> bool: ...  # noqa: D102
    def shutdown(self) -> dict: ...                         # noqa: D102
    def close(self) -> None: ...                            # noqa: D102


class ServerError(RuntimeError):
    """The server answered ``ok: false``; the message is its ``error``."""


class _ClientBase:
    """Shared convenience methods over the raw ``op`` messages."""

    #: Automatic retries of a ``busy`` submit (bounded admission queue
    #: full) before :class:`ServerBusy` propagates to the caller.
    busy_retries: int = 8

    #: Tenant identity stamped on every submit frame (the server's
    #: ``tenants`` table resolves it; "default" when tenancy is off).
    tenant: str = "default"

    def _rpc(self, **msg: Any) -> dict:
        raise NotImplementedError

    @staticmethod
    def _check(resp: Any) -> dict:
        """Turn a raw response into a dict or the right exception."""
        if resp is None:
            raise ConnectionError("server closed the connection")
        if not resp.get("ok"):
            if resp.get("busy"):
                raise ServerBusy(float(resp.get("retry_after", 0.5)))
            if resp.get("quota_exceeded"):
                # Never auto-retried: unlike ``busy``, waiting cannot
                # free a quota — the refusal goes straight to the caller.
                raise QuotaExceeded(
                    str(resp.get("tenant", "?")),
                    str(resp.get("resource", "?")),
                    limit=resp.get("limit"), used=resp.get("used"),
                    detail=resp.get("error"))
            raise ServerError(resp.get("error", "unknown server error"))
        return resp

    def hello(self) -> dict:
        """Server identity, schedule mode, and registered workflows."""
        return self._rpc(op="hello")

    def submit(self, workflow: str, params: Mapping[str, Any]
               | None = None, name: str | None = None,
               timeout: float | None = None,
               priority: int = 0) -> str:
        """Submit a registered workflow by name; returns the job id.

        ``timeout`` bounds the job's server-side *running* time (expiry
        cancels it — status ``cancelled``); ``priority`` sets the
        dispatch class (higher dispatches first). A ``busy`` response
        (bounded admission queue full) is retried after the server's
        ``retry_after`` hint, ``busy_retries`` times, then raises
        :class:`~repro.serve.protocol.ServerBusy`. A ``quota_exceeded``
        refusal raises :class:`~repro.serve.protocol.QuotaExceeded`
        immediately (never retried — the quota will not free itself)."""
        attempts = 0
        while True:
            try:
                resp = self._rpc(op="submit", workflow=workflow,
                                 params=dict(params or {}), name=name,
                                 timeout=timeout, priority=priority,
                                 tenant=self.tenant)
                return resp["job"]
            except ServerBusy as e:
                attempts += 1
                if attempts > self.busy_retries:
                    raise
                time.sleep(e.retry_after)

    def estimate(self, workflow: str, params: Mapping[str, Any]
                 | None = None) -> dict:
        """Marginal-compute estimate for a candidate submission.

        Never enqueues anything: the server compiles the candidate under
        its shared nonce map and prices its unique signatures against
        the store, live leaders, and queued siblings — see
        ``SessionServer.estimate_marginal_cost`` for the returned
        fields (``marginal_s``, ``hit_s``, ``follow_s``, ...)."""
        return self._rpc(op="estimate", workflow=workflow,
                         params=dict(params or {}))

    def wait(self, job: str, timeout: float | None = None,
             detail: bool = False) -> dict:
        """Block until ``job`` finishes; returns its summary dict.

        ``detail=True`` adds the computed-signature lists (see
        ``SessionServer.job_summary``)."""
        return self._rpc(op="wait", job=job, timeout=timeout,
                         detail=detail)

    def job(self, job: str, detail: bool = False) -> dict:
        """Non-blocking job summary (``detail`` as in :meth:`wait`)."""
        return self._rpc(op="job", job=job, detail=detail)

    def cancel(self, job: str) -> bool:
        """Stop a queued or running job (cooperative: the executor
        settles leases/pins/reservations and the job reports status
        ``cancelled``). False when unknown or already finished."""
        return bool(self._rpc(op="cancel", job=job)["cancelled"])

    def forget(self, job: str) -> bool:
        """Release a finished job's server-side record (frees its
        outputs); False when unknown or still running."""
        return bool(self._rpc(op="forget", job=job)["forgotten"])

    def status(self) -> dict:
        """Server status snapshot (queue depth, slots, pool, store)."""
        return self._rpc(op="status")

    def multiplicity(self, sig: str) -> int:
        """Live cross-client multiplicity of one signature."""
        return int(self._rpc(op="multiplicity", sig=sig)["multiplicity"])

    def drain(self, timeout: float | None = None) -> bool:
        """Ask the server to stop accepting and finish live work."""
        return bool(self._rpc(op="drain", timeout=timeout)["drained"])

    def shutdown(self) -> dict:
        """Request server shutdown (graceful: submitted work finishes)."""
        return self._rpc(op="shutdown")


class ServerClient(_ClientBase):
    """Synchronous socket client: one request/response per call.

    One instance wraps one connection and is not thread-safe; concurrent
    clients each open their own (``submit`` returns immediately, so a
    single client can still keep many jobs in flight and ``wait`` on them
    in turn).

    ``timeout`` is the per-RPC socket timeout (applied to the wrapped
    socket); ``reconnect`` is a zero-arg factory returning a fresh
    *connected* socket, used to replace a connection after any socket
    error — see the module docstring for the resend rules. The
    ``connect_unix`` / ``connect_tcp`` helpers install both.
    """

    # Ops safe to resend after a connection died mid-RPC: each is a pure
    # query or naturally idempotent (cancel/forget/drain re-apply to the
    # same state; "wait" just re-waits; "estimate" never mutates).
    # "submit" is deliberately absent.
    _IDEMPOTENT = frozenset({"hello", "status", "job", "wait", "forget",
                             "multiplicity", "drain", "cancel",
                             "shutdown", "estimate"})

    def __init__(self, sock: socket.socket, *,
                 timeout: float | None = None,
                 reconnect: Callable[[], socket.socket] | None = None,
                 tenant: str = "default"):
        """Wrap a connected socket; see the class docstring for knobs."""
        self._sock = sock
        self.timeout = timeout
        self._reconnect = reconnect
        self.tenant = str(tenant)
        if timeout is not None:
            self._sock.settimeout(timeout)

    def _rpc(self, **msg: Any) -> dict:
        return self._check(self._roundtrip(msg))

    def _roundtrip(self, msg: dict) -> Any:
        """One send/recv, with a single reconnect-and-retry when safe.

        Any socket error poisons the connection (a late reply would
        desynchronize the frame stream), so it is always replaced; the
        request is *resent* only when it provably never reached the
        server (the send itself failed) or the op is idempotent — a
        ``submit`` that may have landed must error out, not run twice.
        """
        sent = False
        try:
            send_msg(self._sock, msg)
            sent = True
            return recv_msg(self._sock)
        except OSError:
            # socket.timeout is an OSError (and TimeoutError) — a recv
            # timeout lands here too and also poisons the connection.
            if self._reconnect is None:
                raise
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = self._reconnect()
            if self.timeout is not None:
                self._sock.settimeout(self.timeout)
            if sent and msg.get("op") not in self._IDEMPOTENT:
                raise
            send_msg(self._sock, msg)
            return recv_msg(self._sock)

    def wait(self, job: str, timeout: float | None = None,
             detail: bool = False) -> dict:
        """Block until ``job`` finishes; returns its summary dict.

        With a socket timeout configured, the wait is chunked into RPCs
        each shorter than that timeout, so waiting on a long job is
        indistinguishable from a sequence of quick queries — a slow
        *job* never trips the dead-*server* detector. The overall
        ``timeout`` (None = forever) still raises
        :class:`TimeoutError` exactly like the unchunked call."""
        if self.timeout is None:
            return super().wait(job, timeout, detail)
        chunk = max(0.05, self.timeout * 0.5)
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        while True:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            step = chunk if left is None else min(chunk, left)
            try:
                return self._rpc(op="wait", job=job, timeout=step,
                                 detail=detail)
            except ServerError as e:
                if not str(e).startswith("TimeoutError"):
                    raise
                if left is not None and left <= chunk:
                    raise TimeoutError(str(e)) from None

    def close(self) -> None:
        """Close the connection (idempotent)."""
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class InProcessClient(_ClientBase):
    """Protocol-faithful client for a server in the same process.

    Routes every call through ``SessionServer._handle`` — the same code
    path socket connections hit — so responses are byte-for-byte what the
    wire would carry, minus the framing (including the ``busy``
    backpressure shape, which surfaces as
    :class:`~repro.serve.protocol.ServerBusy` with the same automatic
    submit retries). ``shutdown`` additionally joins the server (sockets
    get that for free from the connection handler).
    """

    def __init__(self, server: SessionServer, *,
                 tenant: str = "default"):
        """Wrap a live server; calls go through its ``_handle``."""
        self._server = server
        self.tenant = str(tenant)

    def _rpc(self, **msg: Any) -> dict:
        return self._check(self._server._handle(msg))

    def shutdown(self) -> dict:
        """Request shutdown and join the server before returning."""
        resp = super().shutdown()
        self._server.shutdown()
        return resp

    def close(self) -> None:
        """No-op (kept for interface parity with ServerClient)."""


def connect_unix(path: str, *, timeout: float | None = None,
                 tenant: str = "default") -> ServerClient:
    """Connect to a session server's unix domain socket.

    ``timeout`` (seconds) bounds every socket operation and arms the
    client's reconnect-on-error path; None keeps the legacy blocking
    behavior. ``tenant`` is stamped on every submit frame."""
    def dial() -> socket.socket:
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        if timeout is not None:
            sock.settimeout(timeout)
        sock.connect(path)
        return sock

    return ServerClient(dial(), timeout=timeout, reconnect=dial,
                        tenant=tenant)


def connect_tcp(host: str, port: int, *, timeout: float | None = None,
                tenant: str = "default") -> ServerClient:
    """Connect to a session server's TCP endpoint.

    ``timeout`` (seconds) bounds every socket operation and arms the
    client's reconnect-on-error path; None keeps the legacy blocking
    behavior. ``tenant`` is stamped on every submit frame."""
    def dial() -> socket.socket:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        if timeout is not None:
            sock.settimeout(timeout)
        sock.connect((host, port))
        return sock

    return ServerClient(dial(), timeout=timeout, reconnect=dial,
                        tenant=tenant)


def connect(target: "SessionServer | Client | str | tuple[str, int]", *,
            timeout: float | None = None,
            tenant: str = "default") -> Client:
    """One entry point for every transport; returns a :class:`Client`.

    Dispatch on ``target``:

    * a live :class:`~repro.serve.server.SessionServer` → in-process
      client (the protocol handler is exercised, no socket);
    * ``(host, port)`` tuple → TCP;
    * ``"host:port"`` string → TCP;
    * any other string → unix-domain socket path;
    * an existing client — anything structurally satisfying
      :class:`Client`, including a
      :class:`~repro.serve.router.FleetRouter` — → returned unchanged
      (lets APIs accept "server, address, router, or client" uniformly —
      the search driver does).

    ``timeout`` is forwarded to the socket transports (per-RPC bound +
    reconnect-on-error, see :func:`connect_unix`); it is meaningless —
    and ignored — for the in-process transport. ``tenant`` is the
    identity stamped on every submit (ignored for an existing client,
    which keeps its own).
    """
    if isinstance(target, SessionServer):
        return InProcessClient(target, tenant=tenant)
    if isinstance(target, _ClientBase):
        return target
    if isinstance(target, tuple) and len(target) == 2:
        return connect_tcp(str(target[0]), int(target[1]), timeout=timeout,
                           tenant=tenant)
    if isinstance(target, str):
        host, sep, port = target.rpartition(":")
        if sep and port.isdigit() and host and "/" not in host:
            return connect_tcp(host, int(port), timeout=timeout,
                               tenant=tenant)
        return connect_unix(target, timeout=timeout, tenant=tenant)
    if isinstance(target, Client):
        # Structural match (runtime_checkable Protocol): a FleetRouter
        # or any client-shaped object passes through unchanged.
        return target
    raise TypeError(
        f"connect() expects a SessionServer, client, address string, or "
        f"(host, port) tuple; got {type(target).__name__}")
