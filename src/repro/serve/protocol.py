"""Wire protocol of the session server: length-prefixed JSON frames.

Transport-agnostic (the same frames flow over a unix socket or TCP): each
frame is a 4-byte big-endian payload length followed by that many bytes of
UTF-8 JSON. One request frame yields exactly one response frame on the
same connection; connections are sequential (a client that wants parallel
submissions opens several connections or submits first and waits later —
``submit`` returns immediately with a job id).

Request messages (``op`` selects the operation)::

    {"op": "hello"}
    {"op": "submit", "workflow": <registry name>, "params": {...},
     "name": <optional job label>, "timeout": <optional s>,
     "priority": <optional int, default 0; higher dispatches first>,
     "tenant": <optional tenant id, default "default">}
    {"op": "estimate", "workflow": <registry name>, "params": {...}}
    {"op": "job",    "job": <job id>,                  # non-blocking status
     "detail": <optional bool>}
    {"op": "wait",   "job": <job id>, "timeout": <s>,  # blocks until done
     "detail": <optional bool>}
    {"op": "cancel", "job": <job id>}                  # stop queued/running
    {"op": "forget", "job": <job id>}                  # drop a finished job
    {"op": "status"}
    {"op": "multiplicity", "sig": <signature>}
    {"op": "drain",  "timeout": <optional s>}
    {"op": "shutdown"}

Responses always carry ``ok`` (bool); failures carry ``error`` (str).
``submit`` responds ``{"ok": true, "job": id}``; ``wait``/``job`` respond
with a job summary (status, timings, execution counts, JSON-coerced
outputs — see :func:`jsonable`); with ``detail: true`` the summary's
``execution`` block also lists ``computed_sigs`` /
``blind_computed_sigs`` for fleet duplicate-compute accounting. A
``wait`` that times out responds ``ok: false`` with a ``TimeoutError:``
message. The server retains the last ``max_finished_jobs`` summaries;
``forget`` releases one eagerly.

``estimate`` prices a *candidate* submission without enqueueing it:
the response carries ``total_s`` / ``marginal_s`` / ``hit_s`` /
``follow_s`` / ``queued_shared_s`` plus node counts (see
``SessionServer.estimate_marginal_cost``). The search driver orders its
frontier with this op.

Backpressure: when the server's admission queue is full (``max_queue``),
``submit`` responds ``{"ok": false, "busy": true, "retry_after": <s>,
"error": ...}`` — the request had no effect and should be retried after
``retry_after`` seconds. :class:`ServerClient` does this automatically
(bounded by its ``busy_retries``); in-process callers see
:class:`ServerBusy` raised instead. ``submit``'s optional ``timeout``
bounds the job's *running* time server-side: on expiry the job's cancel
flag fires, the executor stops between nodes, and the job reports status
``cancelled``. ``cancel`` requests the same stop explicitly for a queued
or running job (``{"ok": true, "cancelled": <bool>}``; False when the
job is unknown or already finished).

Tenancy: a server constructed with ``tenants={id: TenantSpec}`` reads
the frame's ``tenant`` field as the caller's identity (clients stamp it
on every submit; see ``connect(..., tenant=)``). A submit that an
exhausted compute quota or a workflow allowlist refuses responds
``{"ok": false, "quota_exceeded": true, "tenant": <id>,
"resource": <"compute_seconds"|"workflow">, "limit": <x>, "used": <y>,
"error": ...}`` — a clean refusal with no effect, surfaced to callers
as :class:`QuotaExceeded`. Unlike ``busy`` it is *not* retried
automatically: the quota will not free itself.

Workflows cross the wire *by registry name*: the server is constructed
with ``registry={name: factory}`` and the client submits ``(name,
params)``; the factory runs server-side. Arbitrary callables never cross
the boundary. In-process callers (tests, ``run_sweep``) can submit real
:class:`~repro.core.workflow.Workflow` objects through
``SessionServer.submit`` directly.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Any

# A frame larger than this is a protocol error, not a big result: outputs
# are summarized by jsonable() before they are framed.
MAX_FRAME_BYTES = 64 * 1024 * 1024

# Array leaves up to this many elements are inlined into result summaries;
# larger ones are reported as shape/dtype stubs.
_INLINE_ARRAY_ELEMS = 64


class ProtocolError(RuntimeError):
    """A malformed or oversized frame was received."""


class ServerBusy(RuntimeError):
    """The server's bounded admission queue is full.

    The submit had no effect; retry after :attr:`retry_after` seconds.
    Raised by ``SessionServer.submit`` (and the in-process client); on
    the wire it travels as the ``busy`` response shape documented in the
    module docstring, and :class:`~repro.serve.client.ServerClient`
    re-raises it once its automatic retries are exhausted.
    """

    def __init__(self, retry_after: float = 0.5):
        super().__init__(
            f"admission queue full; retry in {retry_after:g}s")
        self.retry_after = float(retry_after)


class QuotaExceeded(RuntimeError):
    """A tenant's quota (or workflow allowlist) refused a submission.

    The submit had no effect. Carries the tenant, the exhausted
    ``resource`` (``"compute_seconds"`` or ``"workflow"``), and — for
    metered resources — the ``limit``/``used`` pair. On the wire it
    travels as the ``quota_exceeded`` response shape documented in the
    module docstring; clients re-raise it and never auto-retry (unlike
    ``busy``, waiting cannot help).
    """

    def __init__(self, tenant: str, resource: str,
                 limit: float | None = None, used: float | None = None,
                 detail: str | None = None):
        msg = detail or (
            f"tenant {tenant!r} exceeded {resource} quota"
            + (f" (limit {limit:g}, used {used:g})"
               if limit is not None and used is not None else ""))
        super().__init__(msg)
        self.tenant = tenant
        self.resource = resource
        self.limit = limit
        self.used = used


def send_msg(sock: socket.socket, obj: Any) -> None:
    """Serialize ``obj`` to JSON and write one length-prefixed frame."""
    data = json.dumps(obj).encode("utf-8")
    if len(data) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(data)} bytes")
    sock.sendall(struct.pack(">I", len(data)) + data)


def recv_msg(sock: socket.socket) -> Any | None:
    """Read one frame; returns the decoded object, or None on clean EOF."""
    header = _recv_exact(sock, 4)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {length} bytes")
    data = _recv_exact(sock, length)
    if data is None:
        raise ProtocolError("connection closed mid-frame")
    return json.loads(data.decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly ``n`` bytes. None on clean EOF at a frame boundary;
    :class:`ProtocolError` if the peer vanishes mid-frame."""
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ProtocolError("connection closed mid-frame")
        buf += chunk
    return buf


def jsonable(value: Any) -> Any:
    """Best-effort JSON coercion of a workflow output for the wire.

    Scalars pass through; numpy scalars become Python numbers; small
    arrays are inlined as nested lists; large arrays (and anything else
    unserializable) become descriptive stubs. The authoritative values
    stay server-side in the store — the wire carries a *summary*.
    """
    import numpy as np

    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, (np.floating,)):
        return float(value)
    if isinstance(value, dict):
        return {str(k): jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [jsonable(v) for v in value]
    if isinstance(value, np.ndarray):
        if value.size <= _INLINE_ARRAY_ELEMS:
            return {"__ndarray__": True, "shape": list(value.shape),
                    "dtype": str(value.dtype), "data": value.tolist()}
        return {"__ndarray__": True, "shape": list(value.shape),
                "dtype": str(value.dtype), "data": None}
    try:  # jax arrays and other array-likes
        arr = np.asarray(value)
        return jsonable(arr)
    except Exception:
        return {"__repr__": repr(value)[:256]}
