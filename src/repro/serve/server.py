"""Long-running per-host session server: many clients, one store, global
scheduling.

Helix (the paper) accelerates one developer's loop; PR 2 let K sweep
variants share one store through lease contention. :class:`SessionServer`
is the ROADMAP's next step: a service that *owns* the workdir and
multiplexes every submission — local calls, unix-socket or TCP clients —
onto one shared :class:`~repro.core.store.Store`, one
:class:`~repro.core.costs.CostModel`, one storage-budget ledger, and one
process-wide executor worker pool, scheduling across submissions with
global knowledge (see scheduler.py):

* submissions are compiled at submit time; their signature sets feed a
  live cross-client **multiplicity map**;
* runnable work is ordered **shared-prefix-first**; siblings of an
  in-flight shared computation yield their slot to independent work (they
  would mostly block on the lease) and, when nothing independent remains,
  lease-follow the leader one node behind;
* the multiplicity map feeds OMP as observed amortization
  (``Materializer.multiplicity``), superseding the static horizon≈K
  heuristic of PR 2's sweeps;
* all sessions draw executor workers from one
  :class:`~repro.serve.pool.SharedWorkerPool` instead of pooling
  independently.

``run_sweep`` is now a thin client of this server: a sweep is just K
submissions (see ``repro.core.sweep``).

Because callables cannot cross a wire, remote clients submit workflows *by
registry name* plus JSON params; in-process callers may submit
:class:`~repro.core.workflow.Workflow` objects (or zero-arg factories)
directly. See protocol.py for the frame format and message schema.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import socket
import threading
import time
import uuid
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Mapping

from ..core.config import (UNSET, EngineConfig, ResilienceConfig,
                           StoreConfig, resolve)
from ..core.costs import CostModel
from ..core.dag import State
from ..core.eviction import Evictor
from ..core.executor import JobCancelled
from ..core.locking import StorageLedger
from ..core.omp import Policy, delta_fraction
from ..core.pruning import slice_from_outputs
from ..core.remote import ObjectStore, RemoteStore, as_remote_store
from ..core.session import IterationReport, IterativeSession
from ..core.signature import compute_chunk_signatures, compute_signatures
from ..core.store import Store
from ..core.workflow import Workflow
from .pool import SharedWorkerPool
from .protocol import (QuotaExceeded, ServerBusy, jsonable, recv_msg,
                       send_msg)
from .scheduler import PrefixScheduler, TenantScheduler
from .tenancy import (ScopedLedger, TenantQuota, TenantSpec,
                      resolve_tenant, validate_params)


class SharedNonces:
    """Server-wide nonce map for nondeterministic nodes.

    First access per node name draws the nonce; every later compilation
    reuses it, so identical unseeded operators across clients become
    equivalent (computed once fleet-wide) — morally "fix the seed for this
    server". Signatures still differ across submissions whose node
    *versions* differ.
    """

    def __init__(self) -> None:
        self._nonces: dict[str, str] = {}
        self._lock = threading.Lock()

    def get(self, name: str, default: str | None = None) -> str:
        """Return the pinned nonce for ``name``, drawing it on first use."""
        with self._lock:
            if name not in self._nonces:
                self._nonces[name] = uuid.uuid4().hex
            return self._nonces[name]


class _LiveShareView:
    """Live ``share_sigs`` view over the scheduler's multiplicity map.

    The executor force-persists lease-computed values whose signature is
    ``in`` this set; backing it by the live map (instead of a frozen
    pre-pass snapshot) means a client that arrives *mid-computation* of a
    prefix still gets it persisted. ``extra`` is the server's
    cross-host share set (:meth:`SessionServer.share_across`): the
    multiplicity map only sees *this host's* submissions, so a
    multi-host driver must say which signatures sibling hosts also want
    — otherwise a host running one arm would persist nothing for the
    fleet and every other host would recompute its prefix."""

    def __init__(self, scheduler: PrefixScheduler, extra: set):
        self._scheduler = scheduler
        self._extra = extra

    def __contains__(self, sig: object) -> bool:
        return (self._scheduler.multiplicity(str(sig)) >= 2
                or str(sig) in self._extra)


@dataclasses.dataclass
class Job:
    """One submitted workflow: lifecycle, timings, and result."""

    id: str
    name: str
    workflow: Workflow
    sigs: frozenset
    seq: int
    submitted_at: float
    status: str = "queued"   # queued | running | done | error | cancelled
    dispatched_at: float | None = None
    finished_at: float | None = None
    run_seconds: float = 0.0
    report: IterationReport | None = None
    error: BaseException | None = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # Per-job running-time bound (None = server default). On expiry the
    # cancel flag below fires and the job finishes as ``cancelled``.
    timeout: float | None = None
    # Cooperative cancel flag, threaded through the session into the
    # executor (checked between nodes and inside lease waits). Set by
    # SessionServer.cancel, the job-timeout timer, and non-drain
    # shutdown.
    cancel_event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # Dispatch class: higher dispatches first within the scheduler's
    # blocked/unblocked tiers. The search driver marks promoted rungs so
    # survivors outrank fresh exploratory arms.
    priority: int = 0
    # Submitting tenant (the wire frame's ``tenant`` field). Drives
    # fair-share accounting, quota ledgers, and the tenant-scoped
    # storage budget; "default" when tenancy is not configured.
    tenant: str = "default"

    @property
    def queued_seconds(self) -> float:
        """Time spent waiting for a session slot."""
        end = self.dispatched_at if self.dispatched_at is not None \
            else time.perf_counter()
        return max(0.0, end - self.submitted_at)


class SessionServer:
    """Multiplex many workflow submissions onto one shared store.

    Configuration comes as the three layered dataclasses of
    ``repro.core.config`` — ``engine=`` (:class:`EngineConfig`),
    ``storage=`` (:class:`StoreConfig`), ``resilience=``
    (:class:`ResilienceConfig`) — forwarded to each per-submission
    session. The loose keyword arguments below are the pre-config API:
    they still work, override the dataclasses, and warn once per kwarg
    name (DeprecationWarning). Resolved groups are exposed as
    ``self.engine_config`` / ``self.store_config`` /
    ``self.resilience_config``. Server-level knobs:

    ``registry``
        ``{name: factory}`` of workflows remote clients may submit;
        ``factory(**params)`` runs server-side and returns a ``Workflow``.
    ``n_sessions``
        Session slots: how many submissions run concurrently.
    ``pool_workers``
        Size of the process-wide :class:`SharedWorkerPool` all sessions'
        executors draw from (default: ``max(n_sessions, max_workers)``).
    ``schedule``
        ``"prefix"`` (shared-prefix-first with sibling deferral — the
        point of this server), ``"fifo"`` (arrival order, PR 2's
        lease-contention-only behavior, kept as the benchmark
        baseline), or ``"fair"`` (weighted fair share across tenants
        with prefix-first order *within* each tenant's turn — see
        :class:`~repro.serve.scheduler.TenantScheduler`; weights come
        from ``tenants``).
    ``tenants``
        ``{tenant id: TenantSpec}`` enabling multi-tenant isolation:
        per-tenant fair-share weights, storage/compute quotas, and
        workflow allowlists (``"*"`` is the catch-all spec; without it,
        unknown tenants are refused). Usage is metered in a
        transactional per-tenant ledger (``tenants.json`` next to the
        store ledger) and each job's materializations run against a
        :class:`~repro.serve.tenancy.ScopedLedger`, so a
        quota-exhausted tenant is refused cleanly — never satisfied by
        evicting another tenant's entries. ``None`` (default) disables
        tenancy: every submission is the ``"default"`` tenant,
        unmetered.
    ``param_schemas``
        ``{workflow name: {param: constraint}}`` submission-time
        validation (see :func:`~repro.serve.tenancy.validate_params`):
        a schema is an allowlist — named params are checked against
        their type/range/choices constraint, unnamed ones are rejected
        before the registry factory runs. Workflows without a schema
        accept any params (opt-in per workflow).
    ``share_nondet``
        Pin one nonce map server-wide so identical nondeterministic
        operators are shared across clients (see :class:`SharedNonces`).
    ``nonces``
        Inject a :class:`SharedNonces` instance instead of creating one
        — the multi-host sweep passes one map to all its servers so
        nondeterministic operators stay sweep-equivalent *across*
        hosts.
    ``horizon``
        Static amortization floor forwarded to OMP. ``None`` (default)
        means 1.0 — under ``schedule="prefix"`` the live multiplicity map
        supersedes the old horizon≈K guess, so no static K is needed.
        (``schedule="fifo"`` keeps amortization purely static, exactly
        PR 2's behavior — pass ``horizon=K`` to reproduce it.)
    ``max_finished_jobs``
        Finished jobs retained for late ``wait``/``job`` queries (their
        reports pin workflow outputs in memory). Oldest beyond this are
        evicted; clients can also release one eagerly with the
        ``forget`` op.
    ``evict_to_admit``
        Attach one fleet :class:`~repro.core.eviction.Evictor` shared by
        every hosted session: materializations that do not fit the
        shared budget evict the lowest-benefit-density unleased entries
        (C(n)/l_i × observed reuse), with the scheduler's live
        multiplicity map as a hard veto — entries live clients still
        want are never candidates. Stats surface in ``status()`` and job
        summaries. False restores refuse-on-exhausted. This governs the
        *local* cache tier; the remote tier budgets itself (below).
    ``remote``
        Attach the fleet-shared remote materialization tier (remote.py):
        a :class:`~repro.core.remote.RemoteStore`, an
        :class:`~repro.core.remote.ObjectStore` backend, or a filesystem
        path (shared-mount reference deployment). The deployment shape
        is one server per host, N servers per remote tier: each server's
        local store write-through/read-through caches the shared tier,
        compute leases extend across hosts via TTL lease objects, and
        ``status()`` reports both tiers. A server that *constructed* its
        RemoteStore (str/ObjectStore input) closes it on shutdown; an
        injected instance belongs to the caller.
    ``max_queue``
        Bounded admission: queued (not-yet-running) submissions beyond
        this raise :class:`~repro.serve.protocol.ServerBusy` (the wire
        ``busy`` response, carrying ``busy_retry_after``) instead of
        growing the queue without limit. ``None`` (default) keeps the
        queue unbounded.
    ``job_timeout``
        Default per-job running-time bound in seconds: a job running
        longer has its cancel flag fired and finishes with status
        ``cancelled``. ``None`` (default) means unbounded; a per-submit
        ``timeout`` overrides it.
    ``gc_interval`` / ``gc_min_age``
        Remote-tier hygiene: with a remote attached, a maintenance
        thread runs ``remote.gc_orphans(min_age_seconds=gc_min_age)``
        every ``gc_interval`` seconds, reclaiming data objects whose
        publisher crashed before the commit marker landed.
        ``gc_interval=None`` (default) means 900 s when a remote is
        attached; pass ``0`` to disable. ``gc_min_age`` (default
        3600 s) is the safety age gate — it must comfortably exceed any
        plausible upload duration (see ``gc_orphans``).
    """

    def __init__(self, workdir: str, *,
                 registry: Mapping[str, Callable[..., Workflow]]
                 | None = None,
                 n_sessions: int = UNSET,
                 pool_workers: int | None = UNSET,
                 schedule: str = UNSET,
                 policy: Policy = UNSET,
                 storage_budget_bytes: float = UNSET,
                 max_workers: int = UNSET,
                 prefetch_depth: int = UNSET,
                 async_materialization: bool = UNSET,
                 share_nondet: bool = UNSET,
                 dedupe_inflight: bool = UNSET,
                 dedupe_wait_seconds: float = UNSET,
                 purge_stale: bool = UNSET,
                 horizon: float | None = UNSET,
                 poll_interval: float = 0.05,
                 max_finished_jobs: int = 1024,
                 evict_to_admit: bool = UNSET,
                 remote: RemoteStore | ObjectStore | str | None = UNSET,
                 nonces: SharedNonces | None = None,
                 tenants: Mapping[str, TenantSpec] | None = None,
                 param_schemas: Mapping[str, Mapping[str, Any]]
                 | None = None,
                 max_queue: int | None = UNSET,
                 busy_retry_after: float = UNSET,
                 job_timeout: float | None = UNSET,
                 gc_interval: float | None = UNSET,
                 gc_min_age: float = UNSET,
                 engine: EngineConfig | None = None,
                 storage: StoreConfig | None = None,
                 resilience: ResilienceConfig | None = None):
        eng = resolve(
            "SessionServer", EngineConfig, engine,
            site_defaults=dict(share_nondet=True, dedupe_inflight=True,
                               n_sessions=4),
            legacy=dict(
                n_sessions=("n_sessions", n_sessions),
                pool_workers=("pool_workers", pool_workers),
                schedule=("schedule", schedule),
                policy=("policy", policy),
                max_workers=("max_workers", max_workers),
                prefetch_depth=("prefetch_depth", prefetch_depth),
                async_materialization=("async_materialization",
                                       async_materialization),
                share_nondet=("share_nondet", share_nondet),
                dedupe_inflight=("dedupe_inflight", dedupe_inflight),
                horizon=("horizon", horizon)))
        sto = resolve(
            "SessionServer", StoreConfig, storage,
            site_defaults=dict(shared_budget=True, purge_stale=False),
            legacy=dict(
                storage_budget_bytes=("budget_bytes", storage_budget_bytes),
                purge_stale=("purge_stale", purge_stale),
                evict_to_admit=("evict_to_admit", evict_to_admit),
                remote=("remote", remote),
                gc_interval=("gc_interval", gc_interval),
                gc_min_age=("gc_min_age", gc_min_age)))
        res = resolve(
            "SessionServer", ResilienceConfig, resilience,
            site_defaults=dict(dedupe_wait_seconds=3600.0),
            legacy=dict(
                dedupe_wait_seconds=("dedupe_wait_seconds",
                                     dedupe_wait_seconds),
                max_queue=("max_queue", max_queue),
                busy_retry_after=("busy_retry_after", busy_retry_after),
                job_timeout=("job_timeout", job_timeout)))
        self.engine_config, self.store_config, self.resilience_config = \
            eng, sto, res
        os.makedirs(workdir, exist_ok=True)
        self.workdir = workdir
        self.registry = dict(registry or {})
        self.n_sessions = max(1, int(eng.n_sessions))
        self.policy = eng.policy
        self.storage_budget_bytes = sto.budget_bytes
        self.max_workers = max(1, int(eng.max_workers))
        self.prefetch_depth = eng.prefetch_depth
        self.async_materialization = eng.async_materialization
        self.share_nondet = eng.share_nondet
        self.dedupe_inflight = eng.dedupe_inflight
        self.dedupe_wait_seconds = res.dedupe_wait_seconds
        self.purge_stale = sto.purge_stale
        self.horizon = 1.0 if eng.horizon is None else float(eng.horizon)
        self.poll_interval = poll_interval
        self.max_queue = None if res.max_queue is None \
            else max(1, int(res.max_queue))
        self.busy_retry_after = float(res.busy_retry_after)
        self.job_timeout = res.job_timeout

        # One store / cost model / ledger / worker pool for every session
        # this server hosts. Reconcile the shared budget ledger with disk
        # unless another process's fleet is mid-run on this workdir (its
        # live reservations must not be erased).
        self._owns_remote = not isinstance(sto.remote, RemoteStore)
        self.store = Store(os.path.join(workdir, "store"),
                           remote=as_remote_store(
                               sto.remote,
                               max_retries=res.remote_max_retries,
                               retry_backoff=res.remote_retry_backoff,
                               faults=res.faults),
                           mem_budget_bytes=sto.mem_budget_bytes,
                           mem_writeback=sto.mem_writeback)
        self.cost_model = CostModel(os.path.join(workdir, "costs.json"))
        if not self.store.any_live_lease():
            StorageLedger(self.store.ledger_path).reset(
                float(self.store.total_bytes()))
        self.pool = SharedWorkerPool(
            eng.pool_workers if eng.pool_workers is not None
            else max(self.n_sessions, self.max_workers))
        self.nonces: SharedNonces | None = \
            nonces if nonces is not None \
            else (SharedNonces() if eng.share_nondet else None)
        # Tenancy: spec table, transactional usage ledger, per-workflow
        # param schemas, and the eviction audit log the isolation
        # harness asserts over. All None/empty when tenancy is off.
        self.tenants: dict[str, TenantSpec] | None = \
            dict(tenants) if tenants is not None else None
        self.param_schemas = dict(param_schemas or {})
        self.quota: TenantQuota | None = None
        if self.tenants is not None:
            self.quota = TenantQuota(os.path.join(workdir, "store",
                                                  "tenants.json"))
        self.eviction_log: list[dict] = []
        # "fair" wraps the prefix scheduler: cross-tenant weighted fair
        # share outside, shared-prefix-first inside each tenant's turn.
        inner_mode = "prefix" if eng.schedule == "fair" else eng.schedule
        inner_sched = PrefixScheduler(self.store, self.cost_model,
                                      mode=inner_mode)
        if eng.schedule == "fair":
            weights = {t: s.weight for t, s in (self.tenants or {}).items()}
            self.scheduler = TenantScheduler(inner_sched, weights)
        else:
            self.scheduler = inner_sched
        # Signatures sibling *hosts* also want (multi-host drivers feed
        # this via share_across; the live multiplicity map below only
        # covers this host's own submissions).
        self.share_extra: set[str] = set()
        self._share_view = _LiveShareView(self.scheduler,
                                          self.share_extra)
        # One fleet evictor shared by every hosted session (stats then
        # aggregate server-wide). The scheduler's live multiplicity map
        # is the veto: entries queued/running clients still want are
        # never eviction candidates.
        self.evict_to_admit = bool(sto.evict_to_admit)
        self.evictor: Evictor | None = None
        if self.evict_to_admit and sto.budget_bytes != float("inf"):
            # Same gate as IterativeSession: an unbounded budget can
            # never trigger eviction, and reports should carry the
            # documented "empty when eviction off" shape.
            self.evictor = Evictor(self.store, cost_model=self.cost_model,
                                   live_multiplicity=self.scheduler.is_live,
                                   on_evict=self._note_eviction)

        self._cv = threading.Condition()
        self._jobs: dict[str, Job] = {}
        self._queue: list[Job] = []
        self._running: dict[str, Job] = {}
        self.max_finished_jobs = max(0, int(max_finished_jobs))
        self._finished_order: list[str] = []   # eviction ring (FIFO)
        self._seq = 0
        self._accepting = True
        self._stop = False
        self._held = 0
        self._shutdown_started = False
        self.dispatch_log: list[str] = []

        self._job_pool = ThreadPoolExecutor(
            max_workers=self.n_sessions, thread_name_prefix="helix-serve")
        self._listeners: list[socket.socket] = []
        self._conns: set[socket.socket] = set()
        self._threads: list[threading.Thread] = []
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="helix-serve-dispatch",
            daemon=True)
        self._dispatcher.start()

        # Remote-tier hygiene: the server owning the workdir is the
        # natural place to reclaim crash orphans (entry data whose
        # publisher died before the commit marker) — clients come and
        # go, the server persists. Age-gated (gc_min_age) so an
        # in-flight slow upload is never mistaken for a crash.
        self.gc_min_age = float(sto.gc_min_age)
        self.gc_interval = (sto.gc_interval if sto.gc_interval is not None
                            else (900.0 if self.store.remote is not None
                                  else 0.0))
        self.gc_stats = {"runs": 0, "reclaimed": 0}
        self._maint_stop = threading.Event()
        self._maintenance: threading.Thread | None = None
        if self.gc_interval and self.store.remote is not None:
            self._maintenance = threading.Thread(
                target=self._maintenance_loop, name="helix-serve-maint",
                daemon=True)
            self._maintenance.start()

    def _maintenance_loop(self) -> None:
        """Periodic remote-tier orphan GC (see ``gc_interval``)."""
        while not self._maint_stop.wait(self.gc_interval):
            try:
                n = self.store.remote.gc_orphans(
                    min_age_seconds=self.gc_min_age)
            except Exception:
                continue  # degraded/unreachable tier: try again next tick
            with self._cv:
                self.gc_stats["runs"] += 1
                self.gc_stats["reclaimed"] += int(n)

    def _note_eviction(self, sig: str, ent: dict, freed: float) -> None:
        """Eviction audit observer (``Evictor(on_evict=...)``).

        Records every successful eviction together with the evicted
        signature's *live* state at eviction time — the tenant-isolation
        harness asserts this log never contains a live entry (and the
        store's lease-respecting delete already makes pinned/computing
        entries unevictable), turning "no cross-tenant eviction of
        live/pinned entries" from a claim into a checked invariant.
        """
        self.eviction_log.append({
            "sig": str(sig), "nbytes": float(freed),
            "live": bool(self.scheduler.is_live(sig)),
        })

    # -- submission --------------------------------------------------------
    def submit(self, workflow: Workflow | Callable[[], Workflow], *,
               name: str | None = None,
               timeout: float | None = None,
               priority: int = 0,
               tenant: str = "default") -> Job:
        """Submit a workflow (or a zero-arg factory) for execution.

        Compiles it immediately — under the server's shared nonce map —
        to learn its signature set, registers those signatures in the
        cross-client multiplicity map, and enqueues the job for the
        global scheduler. Returns the :class:`Job` handle; use
        :meth:`wait` for the result. ``timeout`` bounds the job's
        *running* time (default: the server's ``job_timeout``);
        ``priority`` sets the dispatch class (higher dispatches first —
        the search driver marks promoted rungs so survivors outrank
        fresh exploratory arms). Raises
        :class:`~repro.serve.protocol.ServerBusy` when the bounded
        admission queue (``max_queue``) is full — the submission had no
        effect and is safe to retry.

        With tenancy configured, ``tenant`` names the submitting tenant
        (resolved against the ``tenants`` table; unknown tenants raise
        :class:`PermissionError`) and an exhausted compute-seconds quota
        raises :class:`~repro.serve.protocol.QuotaExceeded` here, at
        admission — a clean refusal with no effect, never a hang.
        """
        spec: TenantSpec | None = None
        if self.tenants is not None:
            spec = resolve_tenant(self.tenants, tenant)
            self.quota.check_compute(tenant, spec)
        wf = workflow if isinstance(workflow, Workflow) else workflow()
        dag = wf.build()
        sigs = frozenset(
            compute_signatures(dag, nonces=self.nonces).values())
        with self._cv:
            if not self._accepting:
                raise RuntimeError("server is draining / shut down")
            if (self.max_queue is not None
                    and len(self._queue) >= self.max_queue):
                raise ServerBusy(self.busy_retry_after)
            self._seq += 1
            job = Job(id=f"j{self._seq}-{uuid.uuid4().hex[:8]}",
                      name=name or wf.name or f"job{self._seq}",
                      workflow=wf, sigs=sigs, seq=self._seq,
                      submitted_at=time.perf_counter(),
                      timeout=timeout if timeout is not None
                      else self.job_timeout,
                      priority=int(priority),
                      tenant=str(tenant))
            self._jobs[job.id] = job
            self._queue.append(job)
            self.scheduler.add(job)
            self._cv.notify_all()
        return job

    def submit_named(self, workflow: str, params: Mapping[str, Any]
                     | None = None, *, name: str | None = None,
                     timeout: float | None = None,
                     priority: int = 0,
                     tenant: str = "default") -> Job:
        """Submit a registered workflow by name (the RPC path).

        Submission-time gates, all *before* the registry factory runs:
        the tenant's workflow allowlist (``TenantSpec.workflows``,
        :class:`~repro.serve.protocol.QuotaExceeded` with resource
        ``"workflow"`` on refusal), the workflow's param schema
        (:func:`~repro.serve.tenancy.validate_params`, ``ValueError``
        on violation), then :meth:`submit`'s compute-quota gate.
        """
        if workflow not in self.registry:
            known = ", ".join(sorted(self.registry)) or "none"
            raise KeyError(
                f"unknown workflow {workflow!r}; registered: {known}")
        if self.tenants is not None:
            spec = resolve_tenant(self.tenants, tenant)
            if (spec.workflows is not None
                    and workflow not in spec.workflows):
                raise QuotaExceeded(
                    tenant, "workflow",
                    detail=f"tenant {tenant!r} is not allowed to submit "
                           f"workflow {workflow!r} (allowed: "
                           f"{', '.join(spec.workflows) or 'none'})")
        schema = self.param_schemas.get(workflow)
        if schema is not None:
            validate_params(workflow, dict(params or {}), schema)
        factory = self.registry[workflow]
        wf = factory(**dict(params or {}))
        return self.submit(wf, name=name or workflow, timeout=timeout,
                           priority=priority, tenant=tenant)

    def _materialize_workflow(self, workflow: str | Workflow
                              | Callable[[], Workflow],
                              params: Mapping[str, Any] | None) -> Workflow:
        """Resolve a registry name / instance / factory to a Workflow."""
        if isinstance(workflow, str):
            if workflow not in self.registry:
                known = ", ".join(sorted(self.registry)) or "none"
                raise KeyError(
                    f"unknown workflow {workflow!r}; registered: {known}")
            return self.registry[workflow](**dict(params or {}))
        return workflow if isinstance(workflow, Workflow) else workflow()

    def estimate_marginal_cost(self, workflow: str | Workflow
                               | Callable[[], Workflow],
                               params: Mapping[str, Any] | None = None
                               ) -> dict:
        """Estimate the *marginal* compute a submission would add now.

        Compiles the candidate under the server's shared nonce map,
        slices it to its outputs, and walks the unique signatures of the
        sliced DAG, pricing each with the shared cost model (unseen
        signatures get the 1.0 s prior):

        * already materialized in the store → ``hit_s`` (free at the
          margin);
        * live in a *running* submission's signature set → ``follow_s``
          (a leader is producing it; a submission would lease-follow
          rather than recompute — ``n_live_leases`` counts how many of
          those are under an exclusive compute lease *right now*);
        * wanted by other *queued* submissions → ``queued_shared_s``
          (still marginal, but will be shared if co-scheduled);
        * otherwise pure marginal compute.

        ``marginal_s = total_s − hit_s − follow_s``. This is the search
        driver's frontier-ordering signal (the ``estimate`` RPC): pick
        the candidate with the least marginal compute, tie-breaking
        toward the largest ``follow_s`` so followers draft behind live
        leaders while the shared frontier is still hot. The estimate is
        advisory — racing submissions can change it — and never mutates
        server state (the candidate is *not* enqueued and its
        signatures do not enter the multiplicity map).

        Chunk-granular pricing: a node with a chunk plan (chunks.py) is
        priced at its *delta* — the historical whole-value cost scaled
        by the fraction of its chunks missing from the store
        (``omp.delta_fraction``), exactly how the session will execute
        it. A daily-retrain submission whose source gained one chunk
        therefore estimates near the appended batch's cost, not a cold
        retrain; ``n_chunked`` counts delta-priced nodes and
        ``chunk_hit_s`` the per-chunk savings folded into ``hit_s``.

        Tier-aware hit pricing: ``hit_load_s`` is what the hits will
        actually cost to *serve*, each priced at the cheapest tier that
        holds it (``Store.est_load_seconds(nbytes, sig=...)`` — a
        memory-resident signature is near-free, a remote-only one pays
        fetch bandwidth), and ``n_hit_mem`` counts the hits resident in
        the memory tier. ``marginal_s`` deliberately ignores this load
        cost (hits stay free at the margin, as before) — the fields let
        the search driver tie-break toward candidates whose hits are
        already hot in RAM.
        """
        wf = self._materialize_workflow(workflow, params)
        dag = wf.build()
        sigs = compute_signatures(dag, nonces=self.nonces)
        sliced = dag.subgraph(slice_from_outputs(dag))
        chunk_plans = compute_chunk_signatures(sliced, sigs)
        with self._cv:
            inflight = self._inflight_sigs_locked()
        total = hit = follow = queued_shared = chunk_hit = 0.0
        hit_load = 0.0
        n_hit = n_follow = n_queued = n_lease = n_chunked = 0
        n_hit_mem = 0
        seen: set[str] = set()
        for n in sliced.topological():
            sig = sigs[n]
            if sig in seen:
                continue
            seen.add(sig)
            c = self.cost_model.compute_cost(
                sig, hint=sliced.nodes[n].cost_hint)
            total += c
            if self.store.has(sig):
                hit += c
                n_hit += 1
                if self.store.mem_has(sig):
                    n_hit_mem += 1
                try:
                    m = self.store.meta(sig)
                    nb = (int(m.get("nbytes", 0) or 0)
                          + int(m.get("chunked", {})
                                .get("chunk_bytes", 0) or 0))
                except (OSError, ValueError):
                    nb = 0   # raced a delete — price it as gone
                else:
                    hit_load += self.store.est_load_seconds(nb, sig=sig)
            elif sig in inflight:
                follow += c
                n_follow += 1
                if self.store.computing(sig):
                    n_lease += 1
            else:
                if self.scheduler.multiplicity(sig) > 0:
                    queued_shared += c
                    n_queued += 1
                if n in chunk_plans:
                    # Compute-and-splice: only the missing chunks run.
                    frac = delta_fraction(chunk_plans[n], self.store)
                    if frac < 1.0:
                        saved = c * (1.0 - frac)
                        hit += saved
                        chunk_hit += saved
                        n_chunked += 1
        return {
            "workflow": wf.name, "n_nodes": len(seen),
            "total_s": total, "marginal_s": total - hit - follow,
            "hit_s": hit, "follow_s": follow,
            "queued_shared_s": queued_shared,
            "n_hit": n_hit, "n_follow": n_follow,
            "n_queued_shared": n_queued, "n_live_leases": n_lease,
            "n_chunked": n_chunked, "chunk_hit_s": chunk_hit,
            "hit_load_s": hit_load, "n_hit_mem": n_hit_mem,
        }

    def cancel(self, job: Job | str,
               reason: str = "cancelled by request") -> bool:
        """Stop a queued or running job.

        Queued jobs leave the queue immediately and finish as
        ``cancelled``. Running jobs get their cancel flag set: the
        executor stops between nodes, releases leases/pins/reservations
        through the normal settle path, and the job finishes as
        ``cancelled`` shortly after. Returns False when the job is
        unknown or already finished (idempotent)."""
        job_id = job.id if isinstance(job, Job) else str(job)
        with self._cv:
            j = self._jobs.get(job_id)
            if j is None or j.done.is_set():
                return False
            try:
                self._queue.remove(j)
            except ValueError:
                pass  # dispatched (or dispatching): flag it instead
            else:
                j.status = "cancelled"
                j.error = JobCancelled(reason)
                j.dispatched_at = time.perf_counter()
                j.finished_at = j.dispatched_at
                self.scheduler.remove(j)
                self._retain_finished_locked(j)
                self._cv.notify_all()
                j.done.set()
                return True
            j.cancel_event.set()
            return True

    def share_across(self, sigs) -> None:
        """Mark signatures sibling *hosts* also need (multi-host mode).

        The executor then force-persists them on lease-compute and
        uploads synchronously before the lease releases — without this,
        a host whose own submissions share nothing would persist nothing
        and every other host would recompute the common prefix. The
        multi-host ``run_sweep`` computes the cross-host shared set from
        the submitted jobs' signatures and feeds it here."""
        with self._cv:
            self.share_extra.update(str(s) for s in sigs)

    @contextlib.contextmanager
    def hold_dispatch(self):
        """Pause dispatching while a batch is submitted, so the scheduler
        sees the whole batch's multiplicities before ordering it."""
        with self._cv:
            self._held += 1
        try:
            yield self
        finally:
            with self._cv:
                self._held -= 1
                self._cv.notify_all()

    # -- waiting / inspection ----------------------------------------------
    def wait(self, job: Job | str, timeout: float | None = None) -> Job:
        """Block until ``job`` (handle or id) finishes; returns the Job."""
        j = job if isinstance(job, Job) else self._jobs[job]
        if not j.done.wait(timeout):
            raise TimeoutError(f"job {j.id} still {j.status}")
        return j

    def wait_all(self, jobs: list[Job] | None = None,
                 timeout: float | None = None) -> list[Job]:
        """Wait for the given jobs (default: every submitted job)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        targets = list(jobs) if jobs is not None else list(
            self._jobs.values())
        for j in targets:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            self.wait(j, timeout=left)
        return targets

    def multiplicity(self, sig: str) -> int:
        """Live submissions (queued or running) needing ``sig``."""
        return self.scheduler.multiplicity(sig)

    def status(self) -> dict:
        """JSON-safe snapshot of server state (the ``status`` RPC)."""
        with self._cv:
            snapshot = {
                "workdir": self.workdir,
                "schedule": self.scheduler.mode,
                "accepting": self._accepting,
                "n_sessions": self.n_sessions,
                "queued": len(self._queue),
                "running": len(self._running),
                "total_jobs": len(self._jobs),
                "cancelled": sum(1 for j in self._jobs.values()
                                 if j.status == "cancelled"),
                "max_queue": self.max_queue,
                "gc": dict(self.gc_stats),
                "pool": self.pool.stats(),
                "eviction": (self.evictor.stats.snapshot()
                             if self.evictor is not None else None),
            }
            if self.tenants is not None:
                snapshot["tenants"] = {
                    "usage": self.quota.snapshot(),
                    "fair": (self.scheduler.snapshot()
                             if isinstance(self.scheduler,
                                           TenantScheduler) else None),
                    "n_evictions": len(self.eviction_log),
                    "n_evictions_live": sum(
                        1 for e in self.eviction_log if e["live"]),
                }
        # Store I/O stays outside the dispatch lock: an index read must
        # never stall submits/completions behind a slow filesystem.
        # Per-tier report (used bytes, entry counts, live lease census
        # for local AND remote) — the observability surface the
        # operations guide's troubleshooting table points at;
        # ``store_bytes`` (local tier) is kept for older clients.
        snapshot["tiers"] = self.store.tier_status()
        snapshot["store_bytes"] = snapshot["tiers"]["local"]["bytes"]
        return snapshot

    def job_summary(self, job: Job | str, detail: bool = False) -> dict:
        """JSON-safe summary of one job (the ``job``/``wait`` RPCs).

        ``detail=True`` additionally lists the signatures the job
        actually computed (planned COMPUTE and not deduped into a load)
        and the subset of those that were *blind* computes (not the
        planner's deliberate recompute-cheaper-than-load choice) — the
        raw material for transport-agnostic fleet duplicate-compute
        accounting (see ``SearchReport.wasted_recomputes``)."""
        j = job if isinstance(job, Job) else self._jobs[job]
        out: dict[str, Any] = {
            "job": j.id, "name": j.name, "status": j.status,
            "queued_seconds": round(j.queued_seconds, 6),
            "run_seconds": round(j.run_seconds, 6),
        }
        if j.error is not None:
            out["error"] = f"{type(j.error).__name__}: {j.error}"
        if j.report is not None:
            ex = j.report.execution
            out["execution"] = {
                "n_computed": ex.n_computed, "n_loaded": ex.n_loaded,
                "n_pruned": ex.n_pruned, "n_deduped": len(ex.deduped),
                "total_seconds": round(ex.total_seconds, 6),
                "mat_seconds": round(ex.mat_seconds, 6),
            }
            if detail:
                computed = [n for n, s in ex.states.items()
                            if s is State.COMPUTE and n not in ex.deduped]
                out["execution"]["computed_sigs"] = sorted(
                    j.report.sigs[n] for n in computed)
                out["execution"]["blind_computed_sigs"] = sorted(
                    j.report.sigs[n] for n in computed
                    if n not in ex.chose_compute)
            if j.report.evictions:
                # Fleet evictor-stat deltas over this job's run window
                # (the evictor is shared, so concurrent jobs' windows
                # overlap — these attribute fleet activity, not blame).
                out["execution"]["evictions"] = dict(j.report.evictions)
            out["outputs"] = jsonable(j.report.outputs)
        return out

    # -- scheduling --------------------------------------------------------
    def _inflight_sigs_locked(self) -> set[str]:
        out: set[str] = set()
        for job in self._running.values():
            out |= job.sigs
        return out

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                job = None
                while not self._stop:
                    if (not self._held and self._queue
                            and len(self._running) < self.n_sessions):
                        # pick() always returns a job for a non-empty
                        # queue: blocked siblings are dispatched (they
                        # lease-follow the leader) when nothing
                        # independent is available — never an idle slot.
                        job = self.scheduler.pick(
                            self._queue, self._inflight_sigs_locked())
                        break
                    # Sleep until a submit / completion / hold-release
                    # notifies (the timeout is only a lost-notify guard).
                    self._cv.wait(timeout=1.0)
                if self._stop:
                    return
                self._queue.remove(job)
                job.status = "running"
                job.dispatched_at = time.perf_counter()
                self._running[job.id] = job
                self.dispatch_log.append(job.name)
                if isinstance(self.scheduler, TenantScheduler):
                    # Provisional fair-share charge while the job runs
                    # (replaced by measured seconds at completion) — K
                    # free slots must not all go to one tenant just
                    # because none of its jobs finished yet.
                    self.scheduler.note_dispatch(job)
            self._job_pool.submit(self._run_job, job)

    def _omp_multiplicity(self, sig: str) -> float:
        """Expected future loads of ``sig``: live siblings now, or the
        fleet's historically observed reuse (capped — history should tilt
        the threshold, not nuke it)."""
        live_others = max(0, self.scheduler.multiplicity(sig) - 1)
        hist = self.cost_model.reuse_count(sig)
        return float(max(live_others, min(hist, 64.0)))

    def _job_ledger(self, job: Job) -> ScopedLedger | None:
        """Build the tenant-scoped budget ledger for one job's session.

        None without tenancy (the session constructs the plain fleet
        ledger itself). With it, the job's materializations debit both
        the fleet ledger and its tenant's quota meter, and a tenant-side
        refusal short-circuits evict-to-admit (see
        :class:`~repro.serve.tenancy.ScopedLedger`).
        """
        if self.tenants is None:
            return None
        spec = resolve_tenant(self.tenants, job.tenant)
        fleet = StorageLedger(self.store.ledger_path)
        fleet.ensure(float(self.store.total_bytes()))
        return ScopedLedger(fleet, self.quota, job.tenant,
                            quota_bytes=spec.storage_bytes)

    def _run_job(self, job: Job) -> None:
        t0 = time.perf_counter()
        timer: threading.Timer | None = None
        if job.timeout is not None:
            # Per-submission running-time bound: expiry just fires the
            # same cooperative cancel flag an explicit cancel() uses.
            timer = threading.Timer(job.timeout, job.cancel_event.set)
            timer.daemon = True
            timer.start()
        try:
            sess = IterativeSession(
                self.workdir,
                engine=dataclasses.replace(
                    self.engine_config, horizon=self.horizon,
                    share_nondet=self.share_nondet,
                    dedupe_inflight=self.dedupe_inflight),
                # The session reuses this server's store instance; its
                # own remote-construction path must stay cold.
                storage=dataclasses.replace(
                    self.store_config, shared_budget=True,
                    purge_stale=self.purge_stale, remote=None,
                    evict_to_admit=self.evict_to_admit),
                resilience=self.resilience_config,
                store=self.store, cost_model=self.cost_model,
                worker_pool=self.pool,
                # One shared fleet evictor (live-multiplicity veto from
                # the scheduler); None keeps refuse-on-exhausted.
                evictor=self.evictor,
                # Tenant-scoped budget ledger (None without tenancy).
                ledger=self._job_ledger(job),
                # Observed amortization belongs to the globally-aware
                # schedules; "fifo" keeps OMP purely static so it
                # remains a faithful PR 2 baseline (pass horizon=K to
                # match).
                multiplicity=(self._omp_multiplicity
                              if self.scheduler.mode in ("prefix", "fair")
                              else None))
            job.report = sess.run(job.workflow, nonces=self.nonces,
                                  share_sigs=self._share_view,
                                  cancel=job.cancel_event)
            job.status = "done"
        except JobCancelled as e:
            # Requested stop (cancel RPC / job timeout / non-drain
            # shutdown), not a failure: the executor already settled
            # leases, pins, and reservations on the way out.
            job.error = e
            job.status = "cancelled"
        except BaseException as e:
            job.error = e
            job.status = "error"
        finally:
            if timer is not None:
                timer.cancel()
            job.run_seconds = time.perf_counter() - t0
            job.finished_at = time.perf_counter()  # same base as the
            # submitted_at/dispatched_at stamps, so deltas are meaningful
            if self.quota is not None:
                # Meter served compute against the tenant's quota
                # (cancelled/errored time still occupied the slot).
                self.quota.charge_compute(job.tenant, job.run_seconds)
            with self._cv:
                if isinstance(self.scheduler, TenantScheduler):
                    self.scheduler.note_finish(job, job.run_seconds)
                self._running.pop(job.id, None)
                self.scheduler.remove(job)
                self._retain_finished_locked(job)
                self._cv.notify_all()
            job.done.set()

    def _retain_finished_locked(self, job: Job) -> None:
        """Bound the finished-job history: a long-running server must not
        pin every past submission's outputs in memory forever."""
        self._finished_order.append(job.id)
        while len(self._finished_order) > self.max_finished_jobs:
            evicted = self._finished_order.pop(0)
            self._jobs.pop(evicted, None)

    def forget(self, job: Job | str) -> bool:
        """Release a finished job's record (and its report) eagerly.

        Returns False when the job is unknown or still queued/running."""
        job_id = job.id if isinstance(job, Job) else job
        with self._cv:
            j = self._jobs.get(job_id)
            if j is None or not j.done.is_set():
                return False
            self._jobs.pop(job_id, None)
            try:
                self._finished_order.remove(job_id)
            except ValueError:
                pass
        return True

    # -- lifecycle ---------------------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Stop accepting submissions and wait for all live work to finish.

        Returns True when the queue and running set emptied within
        ``timeout`` (None = wait forever). The server stays up — already
        submitted jobs complete normally; new submissions are rejected.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            self._accepting = False
            self._cv.notify_all()
            while self._queue or self._running:
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cv.wait(timeout=left if left is not None
                              else self.poll_interval)
        return True

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop the server. ``drain=True`` (default) finishes submitted
        work first (graceful); ``drain=False`` cancels queued *and
        running* jobs — running ones stop cooperatively between nodes
        (leases/pins/reservations released) and report status
        ``cancelled``, not ``error``. Idempotent."""
        with self._cv:
            if self._shutdown_started:
                return
            self._shutdown_started = True
            self._accepting = False
        self._maint_stop.set()
        if drain:
            self.drain(timeout)
        with self._cv:
            for job in self._queue:
                job.status = "cancelled"
                job.error = JobCancelled("server shut down")
                # Freeze queued_seconds at cancellation time (it is
                # computed against "now" while dispatched_at is unset).
                job.dispatched_at = time.perf_counter()
                job.finished_at = job.dispatched_at
                self.scheduler.remove(job)
                job.done.set()
            self._queue.clear()
            if not drain:
                # Non-drain shutdown must not wait an unbounded compute
                # out: fire every running job's cancel flag; the pool
                # join below then returns as soon as each executor
                # reaches its next between-nodes check.
                for job in self._running.values():
                    job.cancel_event.set()
            self._stop = True
            self._cv.notify_all()
        self._dispatcher.join(timeout=30.0)
        self._job_pool.shutdown(wait=True)
        # Settle the write-through: queued uploads must land before the
        # remote handle (and its lease heartbeat) goes away, or a warm
        # remote tier silently misses this host's last materializations.
        if self.store.remote is not None:
            self.store.writer_drain()
            if self._owns_remote:
                self.store.remote.close()
        for sock in self._listeners:
            # close() alone does not wake a thread blocked in accept():
            # the in-progress syscall keeps the listening file
            # description alive (and accepting!) until it returns. Close,
            # then poke the address with a throwaway connection so the
            # blocked accept returns and the loop exits on the dead fd.
            family = sock.family
            try:
                addr = sock.getsockname()
            except OSError:
                addr = None
            try:
                sock.close()
            except OSError:
                pass
            if addr:
                try:
                    dummy = socket.socket(family, socket.SOCK_STREAM)
                    dummy.settimeout(0.5)
                    dummy.connect(addr)
                    dummy.close()
                except OSError:
                    pass
                if family == socket.AF_UNIX:
                    try:
                        os.unlink(addr)
                    except OSError:
                        pass
        for conn in list(self._conns):
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "SessionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- RPC ---------------------------------------------------------------
    def serve_unix(self, path: str) -> str:
        """Listen on a unix domain socket; returns the bound path.

        A stale socket file (dead previous server) is removed; a *live*
        one is refused rather than hijacked — restarting over a
        still-draining server must fail loudly, not steal its clients.
        """
        if os.path.exists(path):
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                probe.settimeout(0.5)
                probe.connect(path)
                probe.close()
                raise RuntimeError(
                    f"another server is live on {path}")
            except (ConnectionRefusedError, FileNotFoundError,
                    socket.timeout, TimeoutError):
                probe.close()
                os.unlink(path)   # dead leftover: safe to reclaim
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(path)
        self._start_listener(sock)
        return path

    def serve_tcp(self, host: str = "127.0.0.1",
                  port: int = 0) -> tuple[str, int]:
        """Listen on TCP; returns the bound ``(host, port)``."""
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((host, port))
        self._start_listener(sock)
        return sock.getsockname()

    def _start_listener(self, sock: socket.socket) -> None:
        sock.listen(16)
        self._listeners.append(sock)
        t = threading.Thread(target=self._listen_loop, args=(sock,),
                             name="helix-serve-listen", daemon=True)
        t.start()
        self._threads.append(t)

    def _listen_loop(self, sock: socket.socket) -> None:
        while True:
            try:
                conn, _ = sock.accept()
            except OSError:
                return   # listener closed by shutdown
            self._conns.add(conn)
            t = threading.Thread(target=self._conn_loop, args=(conn,),
                                 name="helix-serve-conn", daemon=True)
            t.start()
            # Prune dead handler threads so a long-running server's
            # bookkeeping stays O(live connections), not O(ever accepted).
            self._threads = [x for x in self._threads if x.is_alive()]
            self._threads.append(t)

    def _conn_loop(self, conn: socket.socket) -> None:
        try:
            while True:
                try:
                    msg = recv_msg(conn)
                except Exception:
                    return
                if msg is None:
                    return
                resp = self._handle(msg)
                try:
                    send_msg(conn, resp)
                except OSError:
                    return
                if isinstance(msg, dict) and msg.get("op") == "shutdown":
                    # Reply first, then stop the server from a separate
                    # thread (shutdown joins pools this handler is not
                    # part of, but keep the reply latency minimal).
                    threading.Thread(target=self.shutdown,
                                     daemon=True).start()
                    return
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, msg: Any) -> dict:
        """Serve one protocol request (shared by socket handlers and the
        in-process client — see protocol.py for the schema)."""
        if not isinstance(msg, dict):
            return {"ok": False, "error": "message must be a JSON object"}
        op = msg.get("op")
        try:
            if op == "hello":
                return {"ok": True, "server": "helix-session-server",
                        "workdir": self.workdir,
                        "schedule": self.scheduler.mode,
                        "workflows": sorted(self.registry)}
            if op == "submit":
                try:
                    job = self.submit_named(msg.get("workflow", ""),
                                            msg.get("params"),
                                            name=msg.get("name"),
                                            timeout=msg.get("timeout"),
                                            priority=int(
                                                msg.get("priority", 0)),
                                            tenant=str(
                                                msg.get("tenant",
                                                        "default")))
                except ServerBusy as e:
                    # Backpressure, not failure: the submit had no
                    # effect; the client should retry after the hint.
                    return {"ok": False, "busy": True,
                            "retry_after": e.retry_after,
                            "error": str(e)}
                except QuotaExceeded as e:
                    # Clean per-tenant refusal: no effect, not retried
                    # (the quota will not free itself) — see protocol.py.
                    return {"ok": False, "quota_exceeded": True,
                            "tenant": e.tenant, "resource": e.resource,
                            "limit": e.limit, "used": e.used,
                            "error": str(e)}
                return {"ok": True, "job": job.id, "name": job.name}
            if op == "estimate":
                return {"ok": True, **self.estimate_marginal_cost(
                    msg.get("workflow", ""), msg.get("params"))}
            if op == "cancel":
                return {"ok": True,
                        "cancelled": self.cancel(str(msg.get("job", "")))}
            if op in ("job", "wait"):
                job_id = msg.get("job")
                if job_id not in self._jobs:
                    return {"ok": False, "error": f"unknown job {job_id!r}"}
                job = self._jobs[job_id]
                if op == "wait" and not job.done.wait(msg.get("timeout")):
                    # Mirror SessionServer.wait: a timeout is an error the
                    # client can catch, never a partial summary the
                    # caller would mistake for a finished job.
                    return {"ok": False, "error":
                            f"TimeoutError: job {job_id} still "
                            f"{job.status}"}
                return {"ok": True, **self.job_summary(
                    job, detail=bool(msg.get("detail")))}
            if op == "forget":
                return {"ok": True,
                        "forgotten": self.forget(str(msg.get("job", "")))}
            if op == "status":
                return {"ok": True, **self.status()}
            if op == "multiplicity":
                sig = str(msg.get("sig", ""))
                return {"ok": True, "sig": sig,
                        "multiplicity": self.multiplicity(sig)}
            if op == "drain":
                return {"ok": True, "drained": self.drain(
                    msg.get("timeout"))}
            if op == "shutdown":
                return {"ok": True, "stopping": True}
            return {"ok": False, "error": f"unknown op {op!r}"}
        except Exception as e:
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}
