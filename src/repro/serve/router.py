"""Consistent-hash fleet router: N session-server shards, one client.

One :class:`~repro.serve.server.SessionServer` owns one workdir; scaling
past a single host means N servers — and the whole value of the shared
substrate (warm signature prefixes, live multiplicity, compute-once)
depends on *which* shard a submission lands on. :class:`FleetRouter`
speaks the :class:`~repro.serve.client.Client` protocol (so the search
driver, ``connect()``, and every example work against it unchanged) and
adds the placement policy:

* **Prefix-affine routing** — the route key of a submission is the hash
  of its workflow's *source-node signatures* (the nodes with no parents,
  compiled under the router's own nonce map). Sweep arms that share a
  data/featurization prefix share sources, hence share a route key,
  hence land on the same shard — where that prefix is already cached and
  the live multiplicity map actually sees the siblings. Arms over
  different datasets spread out.
* **Rendezvous (highest-random-weight) hashing** — ``shard_for(key)``
  picks the live shard maximizing ``sha256(shard_id + key)``. Adding or
  removing a shard moves only the keys whose argmax changed — an
  expected ``1/N`` fraction — so a rebalance never reshuffles the whole
  fleet's warm caches (the chaos suite asserts the move fraction).
* **Failover through the cancellation/retry path** — a shard that dies
  mid-job (connection error, or a non-drain shutdown that cancelled the
  job) is marked dead and the job is resubmitted to the rendezvous
  choice among the survivors. With the shards sharing a remote tier
  (remote.py), publish-before-release keeps the retry compute-once
  fleet-wide: whatever the dead shard published is fetched, not
  recomputed.

Like :class:`~repro.serve.client.ServerClient`, a router instance wraps
live connections and is not thread-safe; concurrent callers each build
their own (deterministic hashing makes independent routers agree on
placement). ``route="random"`` (seeded) is the control arm for the
``bench_multitenant`` benchmark — same fleet, placement by coin flip.
"""
from __future__ import annotations

import hashlib
import json
import random
from typing import Any, Callable, Mapping

from ..core.signature import compute_signatures
from ..core.workflow import Workflow
from .client import Client, ServerError, connect
from .protocol import QuotaExceeded, ServerBusy
from .server import SharedNonces


def rendezvous(shard_ids, key: str) -> str:
    """Highest-random-weight choice of a shard for ``key``.

    Pure function of ``(sorted shard ids, key)``: every router instance
    — and every test — computes the same placement, and removing one
    shard re-homes only that shard's keys (their argmax is gone; every
    other key's argmax is untouched). Raises :class:`LookupError` on an
    empty shard set.
    """
    ids = sorted(str(s) for s in shard_ids)
    if not ids:
        raise LookupError("no live shards")
    return max(ids, key=lambda sid: hashlib.sha256(
        f"{sid}:{key}".encode()).digest())


class FleetRouter:
    """Route submissions across N session-server shards (Client-shaped).

    ``shards`` maps shard id → anything :func:`~repro.serve.client.connect`
    accepts (a live :class:`~repro.serve.server.SessionServer`, a unix
    socket path, ``(host, port)``, or an existing client). ``registry``
    — the same name→factory table the shards serve — lets the router
    compile a submission locally to derive its prefix-affine route key;
    without it, routing degrades to hashing ``(workflow, params)``
    (deterministic, but arms sharing a prefix no longer co-locate).
    ``timeout``/``tenant`` forward to each shard connection;
    ``route="random"`` + ``seed`` give the benchmark's randomized
    placement control.
    """

    def __init__(self, shards: Mapping[str, Any], *,
                 registry: Mapping[str, Callable[..., Workflow]]
                 | None = None,
                 nonces: SharedNonces | None = None,
                 timeout: float | None = None,
                 tenant: str = "default",
                 route: str = "hash",
                 seed: int = 0):
        """Connect every shard; see the class docstring for knobs."""
        if route not in ("hash", "random"):
            raise ValueError(f"unknown route mode: {route!r}")
        self.tenant = str(tenant)
        self.registry = dict(registry or {})
        self.nonces = nonces if nonces is not None else SharedNonces()
        self.route = route
        self._rng = random.Random(seed)
        self._clients: dict[str, Client] = {}
        self._targets: dict[str, Any] = {}
        self._dead: set[str] = set()
        self._timeout = timeout
        for sid, target in shards.items():
            self._targets[str(sid)] = target
            self._clients[str(sid)] = connect(target, timeout=timeout,
                                              tenant=tenant)
        if not self._clients:
            raise ValueError("FleetRouter needs at least one shard")
        # job id -> submission record for re-routing on shard death.
        self._jobs: dict[str, dict] = {}
        # Failovers performed, for status()/tests.
        self.failovers = 0

    # -- placement ---------------------------------------------------------
    def live_shards(self) -> list[str]:
        """Shard ids currently considered alive (sorted)."""
        return sorted(s for s in self._clients if s not in self._dead)

    def route_key(self, workflow: str,
                  params: Mapping[str, Any] | None = None) -> str:
        """Prefix-affine route key for a submission.

        With the workflow's factory available: compile it under the
        router's nonce map and hash the sorted *source-node* signatures
        — identical for every arm sharing the same input data/config
        nodes, different across datasets. Fallback (no registry entry):
        hash the workflow name + canonical params JSON.
        """
        factory = self.registry.get(workflow)
        if factory is not None:
            dag = factory(**dict(params or {})).build()
            sigs = compute_signatures(dag, nonces=self.nonces)
            sources = sorted(sigs[name] for name, node in dag.nodes.items()
                             if not node.parents)
            return hashlib.sha256(
                ",".join(sources).encode()).hexdigest()
        blob = json.dumps([workflow, dict(params or {})], sort_keys=True,
                          default=str)
        return hashlib.sha256(blob.encode()).hexdigest()

    def shard_for(self, key: str) -> str:
        """Rendezvous choice among the *live* shards for ``key``."""
        return rendezvous(self.live_shards(), key)

    def add_shard(self, sid: str, target: Any) -> None:
        """Join a shard (or revive a dead id with a fresh target).

        Rendezvous hashing means only the keys whose argmax becomes the
        new shard move to it — an expected ``1/N`` of the keyspace; the
        rest keep their warm placement.
        """
        sid = str(sid)
        self._targets[sid] = target
        self._clients[sid] = connect(target, timeout=self._timeout,
                                     tenant=self.tenant)
        self._dead.discard(sid)

    def remove_shard(self, sid: str) -> None:
        """Administratively mark a shard dead (its keys re-home)."""
        self._dead.add(str(sid))

    # -- Client protocol ---------------------------------------------------
    def hello(self) -> dict:
        """Router identity plus each live shard's hello."""
        out = {"ok": True, "server": "helix-fleet-router",
               "route": self.route, "shards": {}}
        workflows: set[str] = set()
        for sid in self.live_shards():
            try:
                h = self._clients[sid].hello()
            except (OSError, ServerError) as e:
                h = {"ok": False, "error": str(e)}
            out["shards"][sid] = h
            workflows.update(h.get("workflows", []))
        out["workflows"] = sorted(workflows)
        return out

    def _pick_shard(self, key: str) -> str:
        if self.route == "random":
            live = self.live_shards()
            if not live:
                raise LookupError("no live shards")
            return self._rng.choice(live)
        return self.shard_for(key)

    def submit(self, workflow: str, params: Mapping[str, Any]
               | None = None, name: str | None = None,
               timeout: float | None = None,
               priority: int = 0) -> str:
        """Submit to the routed shard; returns the shard's job id.

        A shard that refuses the connection at submit time is marked
        dead and the submission re-routes among the survivors (up to
        the fleet size). ``busy``/``quota_exceeded`` refusals are *not*
        failover triggers — they come from a healthy shard and carry
        their own semantics (the shard client retries ``busy`` itself).
        """
        key = self.route_key(workflow, params)
        last_err: Exception | None = None
        for _ in range(len(self._clients)):
            try:
                sid = self._pick_shard(key)
            except LookupError:
                break
            try:
                job = self._clients[sid].submit(
                    workflow, params, name=name, timeout=timeout,
                    priority=priority)
            except (ServerBusy, QuotaExceeded):
                raise
            except (OSError, ConnectionError) as e:
                self._dead.add(sid)
                last_err = e
                continue
            self._jobs[job] = {
                "shard": sid, "key": key, "workflow": workflow,
                "params": dict(params or {}), "name": name,
                "timeout": timeout, "priority": priority,
            }
            return job
        raise last_err or LookupError("no live shards")

    def _shard_dead(self, sid: str) -> bool:
        """Probe a shard after a suspicious cancel: unreachable or no
        longer accepting means dead (shutdown), a healthy answer means
        the cancel was a genuine user/timeout cancel."""
        try:
            st = self._clients[sid].status()
        except (OSError, ConnectionError, ServerError):
            return True
        return not st.get("accepting", False)

    def _failover(self, job: str, rec: dict) -> str:
        """Resubmit a dead shard's job among the survivors.

        The retry rides the normal submit path; with a shared remote
        tier, whatever the dead shard already published is a cache hit
        on the new shard — fleet-wide compute-once holds across the
        failover (the chaos suite asserts it).
        """
        self._dead.add(rec["shard"])
        self.failovers += 1
        self._jobs.pop(job, None)
        return self.submit(rec["workflow"], rec["params"],
                           name=rec["name"], timeout=rec["timeout"],
                           priority=rec["priority"])

    def wait(self, job: str, timeout: float | None = None,
             detail: bool = False) -> dict:
        """Wait on the owning shard; fail over if that shard dies.

        Two death signals: the connection errors out (socket shard
        gone), or the job reports ``cancelled`` while its shard stopped
        accepting (non-drain shutdown cancelled it — a *user* cancel on
        a healthy shard is returned as-is, not retried). Either way the
        job is resubmitted via rendezvous among the survivors and the
        wait continues there.
        """
        for _ in range(len(self._clients) + 1):
            rec = self._jobs.get(job)
            if rec is None:
                return self._clients[self.live_shards()[0]].wait(
                    job, timeout=timeout, detail=detail)
            sid = rec["shard"]
            try:
                out = self._clients[sid].wait(job, timeout=timeout,
                                              detail=detail)
            except (OSError, ConnectionError):
                job = self._failover(job, rec)
                continue
            except ServerError:
                raise
            if (out.get("status") == "cancelled"
                    and self._shard_dead(sid)):
                job = self._failover(job, rec)
                continue
            out["job"] = job          # the surviving job id
            out["shard"] = sid
            return out
        raise RuntimeError("failover loop exhausted the fleet")

    def estimate(self, workflow: str, params: Mapping[str, Any]
                 | None = None) -> dict:
        """Estimate on the shard the submission would route to."""
        sid = self._pick_shard(self.route_key(workflow, params))
        out = self._clients[sid].estimate(workflow, params)
        out["shard"] = sid
        return out

    def _owning(self, job: str) -> Client:
        rec = self._jobs.get(job)
        sid = rec["shard"] if rec is not None else self.live_shards()[0]
        return self._clients[sid]

    def job(self, job: str, detail: bool = False) -> dict:
        """Non-blocking summary from the job's owning shard."""
        return self._owning(job).job(job, detail=detail)

    def cancel(self, job: str) -> bool:
        """Cancel on the owning shard (False when unknown/finished)."""
        try:
            return self._owning(job).cancel(job)
        except (OSError, ConnectionError):
            return False

    def forget(self, job: str) -> bool:
        """Forget on the owning shard; drops the routing record too."""
        rec = self._jobs.pop(job, None)
        if rec is None:
            return False
        try:
            return self._clients[rec["shard"]].forget(job)
        except (OSError, ConnectionError):
            return False

    def status(self) -> dict:
        """Fleet snapshot: per-shard status plus router placement state."""
        shards = {}
        for sid in sorted(self._clients):
            if sid in self._dead:
                shards[sid] = {"ok": False, "dead": True}
                continue
            try:
                shards[sid] = self._clients[sid].status()
            except (OSError, ConnectionError, ServerError) as e:
                shards[sid] = {"ok": False, "error": str(e)}
        return {"ok": True, "router": True, "route": self.route,
                "live_shards": self.live_shards(),
                "failovers": self.failovers, "shards": shards}

    def multiplicity(self, sig: str) -> int:
        """Max live multiplicity of ``sig`` across live shards."""
        best = 0
        for sid in self.live_shards():
            try:
                best = max(best, self._clients[sid].multiplicity(sig))
            except (OSError, ConnectionError, ServerError):
                continue
        return best

    def drain(self, timeout: float | None = None) -> bool:
        """Drain every live shard; True iff all drained in time."""
        return all(self._clients[sid].drain(timeout)
                   for sid in self.live_shards())

    def shutdown(self) -> dict:
        """Shut down every live shard (graceful)."""
        out = {"ok": True, "stopped": []}
        for sid in self.live_shards():
            try:
                self._clients[sid].shutdown()
                out["stopped"].append(sid)
            except (OSError, ConnectionError, ServerError):
                continue
        return out

    def close(self) -> None:
        """Close every shard connection (idempotent)."""
        for client in self._clients.values():
            try:
                client.close()
            except OSError:
                pass

    def __enter__(self) -> "FleetRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
