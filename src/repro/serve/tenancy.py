"""Multi-tenancy primitives: quotas, per-tenant accounting, param schemas.

One :class:`~repro.serve.server.SessionServer` multiplexes every client
onto one store and one worker pool. That is the point — shared-prefix
reuse only pays when tenants share the substrate — but sharing without
limits lets one tenant starve the rest (compute), squat the budget
(storage), or submit junk (arbitrary params). This module is the
isolation layer the server composes in when constructed with
``tenants=``:

* :class:`TenantSpec` — one tenant's contract: fair-share ``weight``,
  ``storage_bytes`` / ``compute_seconds`` quotas, and an optional
  workflow allowlist.
* :class:`TenantQuota` — the fleet-shared per-tenant usage ledger
  (bytes reserved, compute seconds served), transactional JSON under a
  file lock exactly like :class:`~repro.core.locking.StorageLedger`,
  so N server processes on one workdir agree on usage.
* :class:`ScopedLedger` — the ledger a tenant's jobs hand to
  :class:`~repro.core.omp.Materializer`: every reservation must fit
  *both* the fleet budget and the tenant's own storage quota, and a
  tenant-side refusal reports ``scope_exhausted`` so the Materializer
  never evicts other tenants' entries to satisfy a quota that eviction
  cannot help (a quota-exhausted tenant degrades gracefully to
  not-materializing; it never silently evicts a neighbor).
* :func:`validate_params` — per-workflow param schemas: the schema is
  an *allowlist* (unknown params are rejected) with per-param type or
  literal-value constraints, checked at submission before the factory
  runs.

Cross-tenant eviction safety is layered, not re-implemented: entries any
live submission still wants are vetoed by the scheduler's multiplicity
map, and pinned/computing entries are protected by the store's leases —
both tenant-agnostic, so no tenant's evict-to-admit can remove another
tenant's live or pinned entries. The server's eviction observer
(``Evictor(on_evict=...)``) records every eviction with its live/pin
state so the tenant-isolation harness *proves* the invariant instead of
assuming it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from ..core.locking import StorageLedger, read_json, update_json
from .protocol import QuotaExceeded


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's serving contract.

    ``weight``
        Fair-share weight for the ``"fair"`` dispatch schedule: over any
        busy interval a tenant is served compute-seconds proportional to
        its weight (see :class:`~repro.serve.scheduler.TenantScheduler`).
    ``storage_bytes``
        Cap on the bytes this tenant's jobs may hold reserved in the
        shared materialization budget (``inf`` = uncapped). Exhaustion
        is graceful: further materializations are refused for this
        tenant only — never satisfied by evicting other tenants.
    ``compute_seconds``
        Cap on cumulative served compute seconds. An exhausted tenant's
        submissions are rejected with the ``quota_exceeded`` wire error
        (clean refusal at admission, not a hang).
    ``workflows``
        Allowlist of registry names this tenant may submit (``None`` =
        any registered workflow).
    """

    weight: float = 1.0
    storage_bytes: float = float("inf")
    compute_seconds: float = float("inf")
    workflows: tuple[str, ...] | None = None


def resolve_tenant(tenants: Mapping[str, TenantSpec],
                   tenant: str) -> TenantSpec:
    """Look up ``tenant``'s spec; ``"*"`` is the catch-all entry.

    Raises :class:`PermissionError` for a tenant the table does not
    know (and has no ``"*"`` default for) — with tenancy configured,
    identity is required.
    """
    spec = tenants.get(tenant)
    if spec is None:
        spec = tenants.get("*")
    if spec is None:
        known = ", ".join(sorted(k for k in tenants if k != "*")) or "none"
        raise PermissionError(
            f"unknown tenant {tenant!r}; configured: {known}")
    return spec


_TYPES = {
    "int": (int,),
    "float": (int, float),     # an int is an acceptable float
    "number": (int, float),
    "str": (str,),
    "bool": (bool,),
}


def validate_params(workflow: str, params: Mapping[str, Any],
                    schema: Mapping[str, Any]) -> None:
    """Check ``params`` against one workflow's schema (an allowlist).

    ``schema`` maps each *allowed* param name to a constraint:

    * a type name — ``"int" | "float" | "number" | "str" | "bool"``;
    * a list/tuple of allowed literal values;
    * a dict ``{"type": <name>, "min": x, "max": y}`` (bounds optional,
      numeric types only).

    Any param not named in the schema is rejected — the schema *is* the
    allowlist, so a registry factory can never be reached with a kwarg
    the operator did not declare. Raises :class:`ValueError` with the
    offending param named.
    """
    for key, value in params.items():
        if key not in schema:
            allowed = ", ".join(sorted(schema)) or "none"
            raise ValueError(
                f"workflow {workflow!r}: param {key!r} not in schema "
                f"(allowed: {allowed})")
        spec = schema[key]
        if isinstance(spec, (list, tuple)):
            if value not in spec:
                raise ValueError(
                    f"workflow {workflow!r}: param {key!r} must be one "
                    f"of {list(spec)!r}, got {value!r}")
            continue
        if isinstance(spec, Mapping):
            tname = spec.get("type", "number")
            lo, hi = spec.get("min"), spec.get("max")
        else:
            tname, lo, hi = str(spec), None, None
        types = _TYPES.get(tname)
        if types is None:
            raise ValueError(
                f"workflow {workflow!r}: param {key!r} has unknown "
                f"schema type {tname!r}")
        # bool is an int subclass; "int"/"float" must not admit it.
        if not isinstance(value, types) or (isinstance(value, bool)
                                            and tname != "bool"):
            raise ValueError(
                f"workflow {workflow!r}: param {key!r} must be {tname}, "
                f"got {type(value).__name__}")
        if lo is not None and value < lo:
            raise ValueError(
                f"workflow {workflow!r}: param {key!r} below min "
                f"{lo!r}: {value!r}")
        if hi is not None and value > hi:
            raise ValueError(
                f"workflow {workflow!r}: param {key!r} above max "
                f"{hi!r}: {value!r}")


class TenantQuota:
    """Fleet-shared per-tenant usage ledger (storage bytes + compute s).

    The on-disk truth is ``{tenant: {"used_bytes": f, "compute_s": f}}``
    updated by read-modify-write transactions under the file lock (see
    :func:`~repro.core.locking.update_json`), the same discipline as
    :class:`~repro.core.locking.StorageLedger` — concurrent server
    processes (or a router's shards sharing one workdir) can never
    double-spend a quota the way in-memory tallies would.
    """

    def __init__(self, path: str):
        """Bind the ledger to its JSON file (created on first write)."""
        self.path = path

    def _get(self, blob: dict, tenant: str) -> dict:
        ent = blob.get(tenant)
        if not isinstance(ent, dict):
            ent = {"used_bytes": 0.0, "compute_s": 0.0}
            blob[tenant] = ent
        return ent

    def snapshot(self) -> dict:
        """Read the whole per-tenant usage table (JSON-safe)."""
        out = read_json(self.path, {})
        return out if isinstance(out, dict) else {}

    def bytes_used(self, tenant: str) -> float:
        """Bytes ``tenant`` currently holds reserved under its quota."""
        ent = self.snapshot().get(tenant, {})
        return float(ent.get("used_bytes", 0.0))

    def compute_used(self, tenant: str) -> float:
        """Compute seconds served to ``tenant`` so far."""
        ent = self.snapshot().get(tenant, {})
        return float(ent.get("compute_s", 0.0))

    def try_reserve_bytes(self, tenant: str, nbytes: float,
                          quota: float) -> bool:
        """Reserve ``nbytes`` against ``tenant``'s storage quota.

        Returns False — with no side effect — when the reservation
        would push the tenant past ``quota``.
        """
        ok = [False]

        def txn(blob):
            ent = self._get(blob, tenant)
            if ent["used_bytes"] + nbytes > quota:
                return None
            ok[0] = True
            ent["used_bytes"] += float(nbytes)
            return blob

        update_json(self.path, txn, {})
        return ok[0]

    def adjust_bytes(self, tenant: str, delta: float) -> None:
        """Shift ``tenant``'s reserved bytes by ``delta`` (clamped ≥ 0)."""
        if delta == 0:
            return

        def txn(blob):
            ent = self._get(blob, tenant)
            ent["used_bytes"] = max(0.0, ent["used_bytes"] + float(delta))
            return blob

        update_json(self.path, txn, {})

    def charge_compute(self, tenant: str, seconds: float) -> None:
        """Add ``seconds`` of served compute to ``tenant``'s meter."""
        if seconds <= 0:
            return

        def txn(blob):
            ent = self._get(blob, tenant)
            ent["compute_s"] += float(seconds)
            return blob

        update_json(self.path, txn, {})

    def check_compute(self, tenant: str, spec: TenantSpec) -> None:
        """Admission gate: raise :class:`QuotaExceeded` when ``tenant``
        has used up its compute-seconds quota. Called at submit time so
        an exhausted tenant gets a clean wire error instead of queueing
        work that will never be paid for."""
        if spec.compute_seconds == float("inf"):
            return
        used = self.compute_used(tenant)
        if used >= spec.compute_seconds:
            raise QuotaExceeded(tenant, "compute_seconds",
                                limit=spec.compute_seconds, used=used)


class ScopedLedger:
    """A tenant-scoped view over the fleet :class:`StorageLedger`.

    Implements the ledger surface :class:`~repro.core.omp.Materializer`
    consumes (``used`` / ``try_reserve`` / ``release`` / ``adjust``)
    with two-phase semantics: a reservation must clear the tenant's own
    storage quota *first*, then the fleet budget — rolling the tenant
    side back when the fleet side refuses. Two extra methods refine the
    Materializer's behavior in tenant mode:

    ``credit_foreign``
        Bytes freed by evicting/purging entries *some other tenant*
        paid for credit the fleet ledger only — this tenant's quota
        meter must not absorb them.
    ``scope_exhausted``
        True when the refusal was the tenant quota, not the fleet
        budget: eviction frees fleet bytes, never tenant-quota room, so
        the Materializer skips evict-to-admit entirely — a
        quota-exhausted tenant can never displace a neighbor's entries
        chasing space it is not allowed to use.
    """

    def __init__(self, fleet: StorageLedger, quota: TenantQuota,
                 tenant: str, quota_bytes: float = float("inf")):
        """Compose the fleet ledger with ``tenant``'s quota meter."""
        self.fleet = fleet
        self.quota = quota
        self.tenant = tenant
        self.quota_bytes = float(quota_bytes)

    def used(self) -> float:
        """Fleet-wide used bytes (the budget the evictor reasons about)."""
        return self.fleet.used()

    def scope_exhausted(self, nbytes: float) -> bool:
        """Would ``nbytes`` exceed the *tenant* quota (fleet aside)?"""
        if self.quota_bytes == float("inf"):
            return False
        return self.quota.bytes_used(self.tenant) + float(nbytes) \
            > self.quota_bytes

    def try_reserve(self, nbytes: float, budget: float) -> bool:
        """Reserve against tenant quota then fleet budget (both or
        neither)."""
        if not self.quota.try_reserve_bytes(self.tenant, nbytes,
                                            self.quota_bytes):
            return False
        if not self.fleet.try_reserve(nbytes, budget):
            self.quota.adjust_bytes(self.tenant, -float(nbytes))
            return False
        return True

    def release(self, nbytes: float) -> None:
        """Undo one of this tenant's own reservations (both ledgers)."""
        self.fleet.release(nbytes)
        self.quota.adjust_bytes(self.tenant, -float(nbytes))

    def adjust(self, delta: float) -> None:
        """Reconcile an estimate with on-disk reality (both ledgers)."""
        self.fleet.adjust(delta)
        self.quota.adjust_bytes(self.tenant, delta)

    def credit_foreign(self, nbytes: float) -> None:
        """Credit bytes this tenant never reserved (fleet ledger only)."""
        self.fleet.release(nbytes)
