"""Family dispatch: one uniform interface over lm.py / encdec.py.

The step functions (train/prefill/decode) live in ``train/steps.py``; this
module only centralizes parameter-tree construction so the launcher, the
checkpointing layer, and the tests agree on structure.
"""
from __future__ import annotations

from typing import Any

import jax

from . import encdec, lm
from .config import ArchConfig


def param_defs(cfg: ArchConfig) -> Any:
    if cfg.family == "audio":
        return encdec.param_defs(cfg)
    return lm.param_defs(cfg)


def init(cfg: ArchConfig, key: jax.Array) -> Any:
    if cfg.family == "audio":
        return encdec.init(cfg, key)
    return lm.init(cfg, key)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, max_len,
                                 enc_len=cfg.encdec.cross_len)
    return lm.init_cache(cfg, batch, max_len)
