from .config import ArchConfig, EncDecCfg, MoECfg, SSMCfg
from .params import P, init_params, param_specs, shardings_for
from . import layers, lm, encdec, moe, ssd, registry

__all__ = [
    "ArchConfig", "EncDecCfg", "MoECfg", "SSMCfg",
    "P", "init_params", "param_specs", "shardings_for",
    "layers", "lm", "encdec", "moe", "ssd", "registry",
]
