"""Mamba-2 layer via State-Space Duality (SSD, arXiv:2405.21060).

Hardware adaptation (DESIGN.md §6): the CUDA reference implements a fused
selective-scan; on TPU we use the SSD *chunked* decomposition, which is
matmul-rich and therefore MXU-native:

  * within a chunk of length L: the quadratic "attention-like" form
    Y_intra = ((C Bᵀ) ∘ decay-mask) · (dt ∘ X)              — three matmuls
  * chunk boundary states:  S_c = (B ∘ dt ∘ decay-to-end)ᵀ · X — one matmul
  * across chunks: a cheap associative scan over per-chunk states,
  * inter-chunk contribution: Y_inter = C · S_prev ∘ decay-from-start.

The per-chunk compute is what the Pallas kernel (kernels/ssd) tiles into
VMEM; this module is the composable JAX implementation (also the oracle).

Decode uses the O(1) recurrent form: h ← h·exp(dt·A) + dt·(B ⊗ x).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import SSMCfg
from .params import P
from .layers import rmsnorm


def ssm_defs(d_model: int, scfg: SSMCfg) -> dict:
    d_in = scfg.expand * d_model
    nheads = d_in // scfg.head_dim
    ns = scfg.d_state
    # in_proj emits [z (d_in), x (d_in), B (ns), C (ns), dt (nheads)]
    zxbcdt = 2 * d_in + 2 * ns + nheads
    return {
        "in_proj": P((d_model, zxbcdt), ("embed", "ssm_inner")),
        "conv_w": P((scfg.d_conv, d_in + 2 * ns), (None, "ssm_inner")),
        "conv_b": P((d_in + 2 * ns,), ("ssm_inner",), init="zeros"),
        "a_log": P((nheads,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": P((nheads,), (None,), init="zeros", dtype=jnp.float32),
        "d_skip": P((nheads,), (None,), init="ones", dtype=jnp.float32),
        "norm_w": P((d_in,), ("ssm_inner",), init="ones", dtype=jnp.float32),
        "out_proj": P((d_in, d_model), ("ssm_inner", "embed")),
    }


def _split_proj(scfg: SSMCfg, d_model: int, zxbcdt: jax.Array):
    d_in = scfg.expand * d_model
    ns = scfg.d_state
    nheads = d_in // scfg.head_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ns], axis=-1)
    return z, xbc, dt, d_in, ns, nheads


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, window d_conv. xbc: (B, S, C); w: (K, C).

    Returns (out, new_state) where state is the last K-1 inputs (for decode).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                   # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_scan_reference(x, dt, a, B, C, chunk: int, h0=None):
    """Chunked SSD. Shapes:
      x: (batch, S, H, P)   — P = head_dim
      dt: (batch, S, H)     — positive step sizes (post-softplus)
      a:  (H,)              — negative decay rates (−exp(a_log))
      B, C: (batch, S, N)   — shared across heads (n_groups=1)
      h0: optional initial state (batch, H, P, N)
    Returns (y (batch,S,H,P), h_final (batch,H,P,N)).
    """
    bsz, S, H, Pd = x.shape
    N = B.shape[-1]
    L = chunk
    S_orig = S
    if S % L:
        # Zero-pad to a chunk multiple: dt=0 ⇒ no decay (exp(0)=1) and no
        # state update, so the final state and the first S outputs are exact.
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L
    xc = x.reshape(bsz, nc, L, H, Pd)
    dtc = dt.reshape(bsz, nc, L, H)
    Bc = B.reshape(bsz, nc, L, N)
    Cc = C.reshape(bsz, nc, L, N)

    da = dtc * a                                   # (b, nc, L, H) negative
    cs = jnp.cumsum(da, axis=2)                    # within-chunk cumulative
    seg_end = cs[:, :, -1:, :]                     # total decay per chunk

    # --- intra-chunk (quadratic in L, matmul form) ---------------------------
    # decay(i←j) = exp(cs_i − cs_j) for i ≥ j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]      # (b,nc,L,L,H)
    ii = np.arange(L)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)              # (b,nc,L,L)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]       # (b,nc,L,L,H)
    y = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xc)

    # --- chunk states -----------------------------------------------------------
    decay_to_end = jnp.exp(seg_end - cs)                    # (b,nc,L,H)
    xdt = xc * (dtc * decay_to_end)[..., None].astype(x.dtype)
    states = jnp.einsum("bcln,bclhp->bchpn", Bc, xdt)       # (b,nc,H,P,N)

    # --- inter-chunk scan ---------------------------------------------------------
    seg = jnp.exp(seg_end[:, :, 0, :])                      # (b,nc,H)

    def scan_fn(h, inp):
        s_c, g_c = inp                                      # state, decay
        h_new = h * g_c[:, :, None, None] + s_c
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros(states.shape[:1] + states.shape[2:], jnp.float32)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(seg, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (b,nc,H,P,N)

    # --- inter-chunk contribution ---------------------------------------------------
    cdec = jnp.exp(cs)                                      # decay from chunk start
    y_inter = jnp.einsum("bcln,bchpn->bclhp",
                         Cc, h_prev) * cdec[..., None]
    y = y + y_inter.astype(y.dtype)
    return y.reshape(bsz, S, H, Pd)[:, :S_orig], h_final


def ssd_decode_step(x, dt, a, B, C, h):
    """Single-token recurrence. x:(b,H,P) dt:(b,H) B,C:(b,N) h:(b,H,P,N)."""
    g = jnp.exp(dt * a)                                     # (b,H)
    upd = (dt[..., None] * x.astype(jnp.float32))[..., None] \
        * B[:, None, None, :]                               # (b,H,P,N)
    h_new = h * g[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C)
    return y.astype(x.dtype), h_new


def ssm_block(cfg, scfg: SSMCfg, p: dict, x: jax.Array,
              state: tuple | None = None, use_kernel: bool = False):
    """Full Mamba-2 mixer. x: (B, S, D).

    state: None for training/prefill-from-scratch, else
    (conv_state (B, K-1, C), h (B, H, P, N)) for decode (S == 1 uses the
    recurrent path).
    Returns (out (B,S,D), new_state).
    """
    bsz, S, d_model = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw, d_in, ns, nheads = _split_proj(scfg, d_model, zxbcdt)
    a = -jnp.exp(p["a_log"])                                # (H,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if state is not None and S == 1:
        conv_state, h = state
        # shift conv state, apply conv at last position
        cat = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        w, b = p["conv_w"], p["conv_b"]
        k = w.shape[0]
        conv_out = sum(cat[:, i + 1 - 1:i + 1 - 1 + 1, :] * w[i]
                       for i in range(k)) + b  # uses last k positions
        conv_out = jax.nn.silu(conv_out)[:, 0]
        new_conv_state = cat[:, -(k - 1):, :]
        xs, B, C = jnp.split(conv_out, [d_in, d_in + ns], axis=-1)
        xh = xs.reshape(bsz, nheads, scfg.head_dim)
        y, h_new = ssd_decode_step(xh, dt[:, 0], a, B, C, h)
        y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
        y = y.reshape(bsz, 1, d_in)
        new_state = (new_conv_state, h_new)
    else:
        conv_state = state[0] if state is not None else None
        h0 = state[1] if state is not None else None
        conv_out, new_conv_state = _causal_conv(
            xbc, p["conv_w"], p["conv_b"], conv_state)
        xs, B, C = jnp.split(conv_out, [d_in, d_in + ns], axis=-1)
        xh = xs.reshape(bsz, S, nheads, scfg.head_dim)
        if use_kernel:
            from ..kernels.ssd import ops as ssd_ops
            y, h_new = ssd_ops.ssd(xh, dt, a, B, C, chunk=scfg.chunk, h0=h0)
        else:
            y, h_new = ssd_scan_reference(xh, dt, a, B, C, scfg.chunk, h0=h0)
        y = y + (xh.astype(jnp.float32)
                 * p["d_skip"][None, None, :, None]).astype(y.dtype)
        y = y.reshape(bsz, S, d_in)
        new_state = (new_conv_state, h_new)

    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"])
    return (y.astype(x.dtype) @ p["out_proj"]).astype(x.dtype), new_state


def init_ssm_state(cfg, scfg: SSMCfg, batch: int):
    d_in = scfg.expand * cfg.d_model
    nheads = d_in // scfg.head_dim
    conv = jnp.zeros((batch, scfg.d_conv - 1, d_in + 2 * scfg.d_state),
                     jnp.bfloat16)
    h = jnp.zeros((batch, nheads, scfg.head_dim, scfg.d_state), jnp.float32)
    return (conv, h)
