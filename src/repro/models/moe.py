"""Mixture-of-Experts FFN.

TPU-native dispatch: instead of a CUDA-style scatter/gather of individual
tokens (or a dense (tokens × experts × capacity) one-hot, which blows memory
at pod batch sizes), we

  1. route with top-k over router logits,
  2. flatten (token, k) assignments and sort by expert id,
  3. build an (experts, capacity, d_model) dispatch tensor via one scatter of
     *indices* (rank-within-expert < capacity keeps the token, else dropped —
     standard capacity-factor semantics),
  4. run both FFN matmuls as a single batched einsum over experts (MXU
     friendly), and
  5. combine back with the top-k gate weights via one segment-sum scatter.

Sharding: expert weights are (E, D, F). The logical-axis resolver
(params.py) binds E→model when divisible (expert parallelism: granite's 32
experts on a 16-way model axis) and otherwise binds F→model (expert tensor
parallelism: qwen2-moe's 60 experts). Under pjit/GSPMD the einsum then
induces either an all-to-all-free EP pattern or a psum over the model axis.

An optional load-balancing aux loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import MoECfg
from .params import P
from . import layers


def moe_defs(d: int, mcfg: MoECfg) -> dict:
    e, f = mcfg.num_experts, mcfg.expert_d_ff
    defs = {
        "router": P((d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": P((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": P((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": P((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if mcfg.num_shared:
        defs["shared"] = layers.mlp_defs(d, mcfg.shared_d_ff)
        defs["shared_gate"] = P((d, 1), ("embed", None), dtype=jnp.float32)
    return defs


def moe_block_sharded(mcfg: MoECfg, p: dict, x: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Expert-tensor-parallel MoE under shard_map (§Perf lever).

    Routing, sort and dispatch run *locally* per data shard (the plain-pjit
    version's global token gather otherwise all-gathers every token to every
    device); each device holds all experts with a 1/TP slice of d_ff and the
    partial outputs psum over the model axis — one (N_local, D) bf16
    all-reduce per MoE layer, no dispatch traffic at all.

    Falls back to the einsum path when no mesh is active (CPU tests).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    from ..sharding.activation import _active_mesh, batch_axes

    mesh = _active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_block(mcfg, p, x)
    sizes = dict(mesh.shape)
    bd = []
    prod = 1
    for a in batch_axes():
        if a in sizes and a != "model" \
                and x.shape[0] % (prod * sizes[a]) == 0:
            bd.append(a)
            prod *= sizes[a]
    bd = tuple(bd)   # axes the batch dim actually divides over (may be ())

    def local(x_l, p_l):
        out, aux = moe_block(mcfg, p_l, x_l, psum_axis="model")
        aux = jax.lax.pmean(aux, axis_name="model")
        for a in bd:
            aux = jax.lax.pmean(aux, axis_name=a)
        return out, aux

    p_specs = {"router": PS(None, None),
               "w_gate": PS(None, None, "model"),   # expert-TP on d_ff
               "w_up": PS(None, None, "model"),
               "w_down": PS(None, "model", None)}
    if mcfg.num_shared:
        p_specs["shared"] = {"w_gate": PS(None, "model"),
                             "w_up": PS(None, "model"),
                             "w_down": PS("model", None)}
        p_specs["shared_gate"] = PS(None, None)
    p_in = {k: p[k] for k in p_specs}
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(PS(bd, None, None), p_specs),
        out_specs=(PS(bd, None, None), PS()),
        check_rep=False,
    )(x, p_in)
    return out, aux


def moe_block_a2a(mcfg: MoECfg, p: dict, x: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """True expert parallelism with all-to-all dispatch (§Perf lever).

    Requires num_experts % model-axis-size == 0 (granite: 32 % 16). Each
    model shard owns E/16 experts with their FULL d_ff; tokens are routed
    locally, exchanged with one all-to-all (k·cf× activation bytes instead
    of expert-TP's full psum per layer), expert-computed, and a2a'd back.
    Falls back to expert-TP shard_map when indivisible / no mesh.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    from ..sharding.activation import _active_mesh, batch_axes

    mesh = _active_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or mcfg.num_experts % dict(mesh.shape)["model"]:
        return moe_block_sharded(mcfg, p, x)
    sizes = dict(mesh.shape)
    n_shards = sizes["model"]
    e_local = mcfg.num_experts // n_shards
    bd = []
    prod = 1
    for a in batch_axes():
        if a in sizes and a != "model" \
                and x.shape[0] % (prod * sizes[a]) == 0:
            bd.append(a)
            prod *= sizes[a]
    bd = tuple(bd)

    def local(x_l, p_l):
        b, s, d = x_l.shape
        n = b * s
        e, k = mcfg.num_experts, mcfg.top_k
        xt = x_l.reshape(n, d)
        logits = xt.astype(jnp.float32) @ p_l["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
        density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
        aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

        # ---- dispatch to (n_shards, cap) send buffer, sorted by expert --
        cap = int(max(1, round(n * k / e * mcfg.capacity_factor))) * e_local
        flat_e = expert_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
        flat_g = gate.reshape(-1)
        tgt = flat_e // e_local                     # owning shard
        order = jnp.argsort(tgt * e + flat_e)       # group by shard, expert
        se, st, sg, stgt = (flat_e[order], flat_t[order], flat_g[order],
                            tgt[order])
        counts = jnp.bincount(stgt, length=n_shards)
        starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(n * k) - starts[stgt]
        keep = rank < cap
        slot = jnp.where(keep, stgt * cap + rank, n_shards * cap)  # OOB→drop
        send_x = jnp.zeros((n_shards * cap, d), x_l.dtype).at[slot].set(
            xt[st], mode="drop")
        send_e = jnp.full((n_shards * cap,), -1, jnp.int32).at[slot].set(
            se, mode="drop")
        send_x = send_x.reshape(n_shards, cap, d)
        send_e = send_e.reshape(n_shards, cap)

        # ---- exchange: every shard receives the tokens for its experts --
        recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0,
                                    concat_axis=0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, "model", split_axis=0,
                                    concat_axis=0, tiled=True)
        rx = recv_x.reshape(n_shards * cap, d)
        shard_id = jax.lax.axis_index("model")
        re_local = recv_e.reshape(-1) - shard_id * e_local  # local expert id
        valid = (recv_e.reshape(-1) >= 0)

        # ---- second-level dispatch to the E_local experts --------------
        cap2 = n_shards * cap // e_local
        order2 = jnp.argsort(jnp.where(valid, re_local, e_local))
        se2 = re_local[order2]
        counts2 = jnp.bincount(jnp.where(valid[order2], se2, e_local),
                               length=e_local + 1)[:e_local]
        starts2 = jnp.concatenate([jnp.zeros(1, counts2.dtype),
                                   jnp.cumsum(counts2)[:-1]])
        rank2 = jnp.arange(n_shards * cap) - starts2[jnp.clip(se2, 0,
                                                              e_local - 1)]
        keep2 = (rank2 < cap2) & valid[order2]
        slot2 = jnp.where(
            keep2, jnp.clip(se2, 0, e_local - 1) * cap2 + rank2,
            e_local * cap2)                                    # OOB→drop
        xe = jnp.zeros((e_local * cap2, d), x_l.dtype).at[slot2].set(
            rx[order2], mode="drop")
        xe = xe.reshape(e_local, cap2, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p_l["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xe, p_l["w_up"])
        ye = jnp.einsum("ecf,efd->ecd", h, p_l["w_down"])

        # ---- undo second-level dispatch, a2a back, combine --------------
        y_sorted = ye.reshape(e_local * cap2, d)[
            jnp.clip(slot2, 0, e_local * cap2 - 1)] \
            * keep2[:, None].astype(ye.dtype)
        inv2 = jnp.zeros_like(order2).at[order2].set(
            jnp.arange(order2.shape[0]))
        y_recv_layout = y_sorted[inv2]              # matches recv_x layout
        back = jax.lax.all_to_all(
            y_recv_layout.reshape(n_shards, cap, d), "model",
            split_axis=0, concat_axis=0, tiled=True).reshape(-1, d)
        y_slots = back[jnp.clip(slot, 0, n_shards * cap - 1)] \
            * (sg * keep.astype(sg.dtype))[:, None].astype(back.dtype)
        out = jnp.zeros((n, d), y_slots.dtype).at[st].add(y_slots)
        if mcfg.num_shared:
            sgw = jax.nn.sigmoid(xt.astype(jnp.float32) @ p_l["shared_gate"])
            partial = layers.mlp_block(p_l["shared"], xt) * sgw.astype(out.dtype)
            out = out + jax.lax.psum(partial, "model")
        aux = jax.lax.pmean(aux, axis_name="model")
        for a in bd:
            aux = jax.lax.pmean(aux, axis_name=a)
        return out.reshape(b, s, d), aux

    p_specs = {"router": PS(None, None),
               "w_gate": PS("model", None, None),   # experts over model (EP)
               "w_up": PS("model", None, None),
               "w_down": PS("model", None, None)}
    if mcfg.num_shared:
        p_specs["shared"] = {"w_gate": PS(None, "model"),
                             "w_up": PS(None, "model"),
                             "w_down": PS("model", None)}
        p_specs["shared_gate"] = PS(None, None)
    p_in = {k: p[k] for k in p_specs}
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(PS(bd, None, None), p_specs),
        out_specs=(PS(bd, None, None), PS()),
        check_rep=False,
    )(x, p_in)
    return out, aux


def moe_block(mcfg: MoECfg, p: dict, x: jax.Array, psum_axis: str | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    n = b * s
    e, k = mcfg.num_experts, mcfg.top_k
    xt = x.reshape(n, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                # (N, k) each
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing loss.
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * router_mean)

    # ---- sort-based capacity dispatch --------------------------------------
    cap = int(max(1, round(n * k / e * mcfg.capacity_factor)))
    flat_e = expert_idx.reshape(-1)                           # (N*k,)
    flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)    # token of slot
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e)                               # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank of each sorted slot within its expert group
    offsets = jnp.cumsum(jnp.bincount(se, length=e))          # (E,)
    starts = jnp.concatenate([jnp.zeros(1, offsets.dtype), offsets[:-1]])
    rank = jnp.arange(n * k) - starts[se]
    keep = rank < cap
    # dropped rows write out-of-bounds (mode="drop" discards them) so they
    # can never collide with a valid rank-0 slot
    slot = jnp.where(keep, se * cap + rank, e * cap)

    # dispatch indices: which token fills each (expert, capacity) slot
    token_for_slot = jnp.zeros(e * cap, jnp.int32).at[slot].set(
        st, mode="drop")
    filled = jnp.zeros(e * cap, bool).at[slot].set(keep, mode="drop")
    xe = xt[token_for_slot].reshape(e, cap, d)
    xe = xe * filled.reshape(e, cap, 1).astype(xe.dtype)

    # ---- expert FFN as batched einsum ---------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # (E, C, D)

    # ---- combine -------------------------------------------------------------
    # gather each kept slot's output and scatter-add into its token
    y_slots = ye.reshape(e * cap, d)[jnp.clip(slot, 0, e * cap - 1)]
    y_slots = y_slots * (sg * keep.astype(sg.dtype))[:, None].astype(y_slots.dtype)
    out = jnp.zeros((n, d), y_slots.dtype).at[st].add(y_slots)

    if mcfg.num_shared:
        sg_w = jax.nn.sigmoid(xt.astype(jnp.float32) @ p["shared_gate"])
        out = out + (layers.mlp_block(p["shared"], xt)
                     * sg_w.astype(out.dtype))
    if psum_axis is not None:
        # expert-TP: routed+shared outputs are partial over the d_ff shards
        out = jax.lax.psum(out, psum_axis)
    return out.reshape(b, s, d), aux
