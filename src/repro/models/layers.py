"""Transformer building blocks: RMSNorm, RoPE / M-RoPE, GQA attention with
sliding windows + KV caches, gated MLP.

All functions are pure; parameters arrive as pytrees built from
``params.P`` definitions. Attention dispatches to the Pallas flash kernel
when ``impl == "flash"`` (TPU target; validated in interpret mode), else uses
the fused-softmax XLA reference (also the dry-run path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .params import P

# A "very large" window meaning global attention (decode masks use
# q_pos - k_pos < window; 2^30 exceeds any context we target).
GLOBAL_WINDOW = 1 << 30


# --------------------------------------------------------------------------- norm
def rmsnorm_defs(d: int) -> P:
    return P((d,), ("embed",), init="ones", dtype=jnp.float32)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w).astype(dt)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple | None = None) -> jax.Array:
    """Rotary embedding.

    x: (B, S, H, D). positions: (B, S) int32, or (3, B, S) for M-RoPE where
    the three streams are (temporal, height, width) ids and
    ``mrope_sections`` gives the number of frequency pairs per stream
    (summing to D//2) — the Qwen2-VL scheme.
    """
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (d/2,)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)                 # (B, S)
        ang = pos[..., None] * freqs                        # (B, S, d/2)
    else:
        assert positions.ndim == 3, "M-RoPE wants (3, B, S) position ids"
        sec = mrope_sections
        assert sum(sec) == d // 2, (sec, d)
        parts = []
        start = 0
        for i, n in enumerate(sec):
            p = positions[i].astype(jnp.float32)            # (B, S)
            parts.append(p[..., None] * freqs[start:start + n])
            start += n
        ang = jnp.concatenate(parts, axis=-1)               # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- attention
def attention_defs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    defs = {
        "wq": P((d, h, hd), ("embed", "heads", None)),
        "wk": P((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": P((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": P((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.use_bias:
        defs["bq"] = P((h, hd), ("heads", None), init="zeros")
        defs["bk"] = P((kv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = P((kv, hd), ("kv_heads", None), init="zeros")
    return defs


def _sdpa_reference(q, k, v, mask) -> jax.Array:
    """Grouped-query scaled-dot-product attention, fp32 softmax.

    q: (B, S_q, KV, G, D) — G = q heads per kv head.
    k, v: (B, S_k, KV, D). mask: broadcastable to (B, KV, G, S_q, S_k).
    """
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out


def _sdpa_chunked(qg, k, v, q_pos, k_pos, *, causal, window, valid_len,
                  chunk: int = 1024) -> jax.Array:
    """Online-softmax attention scanned over KV chunks (flash-style, in
    XLA). Peak memory is O(Sq·chunk) instead of O(Sq·Sk) — the dry-run
    visible analogue of the Pallas kernel (which owns the real-TPU path).

    qg: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D).
    """
    b, sq, kvh, g, d = qg.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(1 << 30))
    nc = (sk + pad) // chunk
    scale = d ** -0.5
    kc = k.reshape(b, nc, chunk, kvh, d)
    vc = v.reshape(b, nc, chunk, kvh, d)
    kpc = k_pos.reshape(b, nc, chunk)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, kp_i = xs                       # (b,chunk,kv,d) …
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_i,
                            preferred_element_type=jnp.float32) * scale
        rel = q_pos[:, None, None, :, None] - kp_i[:, None, None, None, :]
        mask = kp_i[:, None, None, None, :] >= 0
        if causal:
            mask &= rel >= 0
        if window is not None:
            mask &= rel < window
        if valid_len is not None:
            mask &= (kp_i[:, None, None, None, :]
                     < valid_len[:, None, None, None, None])
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.moveaxis(kpc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, -2, 1).reshape(b, sq, kvh, g, d).astype(qg.dtype)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, k_pos: jax.Array,
                  *, causal: bool, window: int | None,
                  valid_len: jax.Array | None = None,
                  impl: str = "reference") -> jax.Array:
    """GQA attention with positional masking.

    q: (B, S_q, H, D); k/v: (B, S_k, KV, D); q_pos: (B, S_q); k_pos: (B, S_k)
    valid_len: optional (B,) number of live cache slots (decode).
    Returns (B, S_q, H, D).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    rel = q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
    mask = jnp.ones((b, 1, 1, sq, k.shape[1]), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    if valid_len is not None:
        mask &= (jnp.arange(k.shape[1])[None, None, None, None, :]
                 < valid_len[:, None, None, None, None])
    if impl == "flash" and sq > 1:
        from ..kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(
            qg.reshape(b, sq, h, d), k, v,
            q_offset=q_pos[:, 0], causal=causal,
            window=window if window is not None else GLOBAL_WINDOW)
        return out
    if impl == "chunked" and sq > 1:
        out = _sdpa_chunked(qg, k, v, q_pos, k_pos, causal=causal,
                            window=window, valid_len=valid_len)
        return out.reshape(b, sq, h, d)
    out = _sdpa_reference(qg, k, v, mask)
    return out.reshape(b, sq, h, d)


def attn_block(cfg, p: dict, x: jax.Array, positions: jax.Array,
               *, window: int | None, causal: bool = True,
               kv_cache: tuple | None = None, cache_pos=None,
               mrope_positions=None) -> tuple[jax.Array, tuple | None]:
    """Self-attention block (no residual/norm — caller owns those).

    kv_cache: optional (k_cache, v_cache) with shape (B, S_max, KV, D);
    cache_pos: scalar int32 — write offset (decode step / prefill fill).
    Returns (out, new_cache).
    """
    b, s, d_model = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    rope_pos = mrope_positions if mrope_positions is not None else positions
    q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.mrope_sections
                   if mrope_positions is not None else None)
    k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.mrope_sections
                   if mrope_positions is not None else None)

    if kv_cache is not None:
        kc, vc = kv_cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_pos, axis=1)
        k_full, v_full = kc, vc
        k_pos = jnp.broadcast_to(jnp.arange(kc.shape[1], dtype=jnp.int32)[None],
                                 (b, kc.shape[1]))
        valid = jnp.broadcast_to(cache_pos + s, (b,))
        new_cache = (kc, vc)
    else:
        k_full, v_full = k, v
        k_pos = positions if positions.ndim == 2 else positions[0]
        valid = None
        new_cache = None

    q_pos = positions if positions.ndim == 2 else positions[0]
    out = gqa_attention(q, k_full, v_full, q_pos, k_pos,
                        causal=causal, window=window, valid_len=valid,
                        impl=cfg.attn_impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def ring_update(kc, vc, kpc, k, v, cache_pos):
    """Ring-buffer cache write with absolute-position tracking.

    kc/vc: (B, W, KV, hd); kpc: (B, W) int32 absolute positions (−big when
    empty); k/v: (B, S, KV, hd) new entries for positions
    [cache_pos, cache_pos+S). Slot = pos % W; for S > W only the last W
    survive (by construction of the window mask nothing older is needed).
    """
    b, w = kpc.shape
    s = k.shape[1]
    if s == 1:
        slot = jax.lax.rem(cache_pos, w)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, slot, 0, 0))
        kpc = jax.lax.dynamic_update_slice(
            kpc, jnp.broadcast_to(cache_pos, (b, 1)).astype(jnp.int32),
            (0, slot))
        return kc, vc, kpc
    last = cache_pos + s - 1
    j = jnp.arange(w, dtype=jnp.int32)
    p = last - jax.lax.rem(last - j, w)       # newest pos ≤ last in slot j
    take = p >= cache_pos                     # slot overwritten by this call
    rel = jnp.clip(p - cache_pos, 0, s - 1)
    gathered_k = jnp.take(k, rel, axis=1).astype(kc.dtype)
    gathered_v = jnp.take(v, rel, axis=1).astype(vc.dtype)
    sel = take[None, :, None, None]
    kc = jnp.where(sel, gathered_k, kc)
    vc = jnp.where(sel, gathered_v, vc)
    kpc = jnp.where(take[None, :], p[None, :], kpc)
    return kc, vc, kpc


def attn_block_ring(cfg, p: dict, x: jax.Array, positions: jax.Array,
                    ring: tuple, cache_pos, window: int
                    ) -> tuple[jax.Array, tuple]:
    """Sliding-window attention against a ring cache (window_cache mode).

    Decode (S==1): write-then-attend over the W ring slots, masking by the
    *stored absolute positions* (ring order is irrelevant to a position
    mask). Prefill (S>1, cache_pos==0): attend within the sequence, then
    ring-write the tail.
    """
    b, s, _ = x.shape
    kc, vc, kpc = ring
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if s == 1:
        kc, vc, kpc = ring_update(kc, vc, kpc, k, v, cache_pos)
        out = gqa_attention(q, kc, vc, positions, kpc,
                            causal=True, window=window,
                            impl="reference")
    else:
        k_pos = positions
        out = gqa_attention(q, k, v, positions, k_pos,
                            causal=True, window=window, impl=cfg.attn_impl)
        kc, vc, kpc = ring_update(kc, vc, kpc, k, v, cache_pos)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (kc, vc, kpc)


def cross_attn_block(cfg, p: dict, x: jax.Array, enc: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (no cache needed: enc is static)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    se = enc.shape[1]
    q_pos = jnp.zeros((b, s), jnp.int32)
    k_pos = jnp.zeros((b, se), jnp.int32)
    out = gqa_attention(q, k, v, q_pos, k_pos, causal=False, window=None,
                        impl="reference")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# --------------------------------------------------------------------------- mlp
def mlp_defs(d: int, d_ff: int) -> dict:
    return {
        "w_gate": P((d, d_ff), ("embed", "mlp")),
        "w_up": P((d, d_ff), ("embed", "mlp")),
        "w_down": P((d_ff, d), ("mlp", "embed")),
    }


def mlp_block(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
