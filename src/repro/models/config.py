"""Architecture configuration dataclasses for the model zoo.

One frozen dataclass describes every assigned architecture; family-specific
blocks (MoE, SSM, enc-dec) are optional sub-configs. ``src/repro/configs/``
holds one instance per assigned arch id.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared: int = 0
    shared_d_ff: int = 0
    every_k_layers: int = 1       # MoE FFN every k-th layer (1 = all layers)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128              # SSD chunk length
    a_init_range: tuple = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int
    dec_layers: int
    cross_len: int = 1500         # encoder output length seen by decoder


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 → d_model // num_heads
    use_bias: bool = False
    rope_theta: float = 10_000.0
    # Sliding-window pattern: (local_count, global_every) e.g. gemma3 = (5, 6)
    # means layers use window except every 6th layer which is global.
    window: int | None = None     # local window size (None = all global)
    global_every: int = 0         # 0 = no global layers when window set
    # M-RoPE (qwen2-vl): rotary dims split into (t, h, w) sections.
    mrope_sections: Optional[tuple] = None
    # hybrid (jamba): attention every k-th layer, SSM elsewhere.
    attn_every: int = 0
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    encdec: Optional[EncDecCfg] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- execution hints (overridable per run) ------------------------------
    remat: str = "block"          # none | block | full
    scan_layers: bool = True
    attn_impl: str = "chunked"    # reference | chunked | flash (Pallas, TPU)
    grad_accum: int = 1           # microbatches per train step
    # vocab-sharded-friendly implementations (§Perf levers): "onehot" turns
    # the embedding gather / xent gold-gather into fused one-hot matmuls so
    # GSPMD never reshards the (vocab, d) table (XLA's sharded-gather
    # fallback replicates it).
    embed_impl: str = "onehot"    # gather | onehot
    xent_impl: str = "onehot"     # gather | onehot
    moe_impl: str = "einsum"      # einsum | shard_map (expert-TP, explicit)
    # Ring KV cache for sliding-window layers (§Perf lever): local layers
    # allocate only `window` slots (ring-written, absolute positions stored
    # alongside so masking is order-independent); global layers keep the
    # full-length cache. gemma3 long_500k: 36.5 GB → ~5.5 GB.
    window_cache: bool = False
    # Preferred launch-level sharding ruleset for training (None → the
    # launcher default "train_2d"). command-r-plus validated "train_fsdp"
    # in §Perf cell B: pure ZeRO-3, batch over all 256 chips, no TP ARs.
    train_ruleset: str | None = None
    # Fully unroll the layer/accum scans. Used by the roofline dry-run:
    # XLA's cost_analysis counts while-loop bodies ONCE, so scanned models
    # under-report FLOPs/bytes by ~layers×accum. Unrolling restores exact
    # counts (slower compile; never used for real runs).
    unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (used for 6·N·D model-FLOPs)."""
        hd = self.resolved_head_dim
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        enc_dec = self.encdec
        n_layers = self.num_layers if enc_dec is None else (
            enc_dec.enc_layers + enc_dec.dec_layers)
        for i in range(n_layers):
            is_ssm = self._layer_is_ssm(i)
            if is_ssm:
                d_in = self.ssm.expand * d
                nheads = d_in // self.ssm.head_dim
                ns = self.ssm.d_state
                total += d * (2 * d_in + 2 * ns + nheads)       # in_proj
                total += (d_in + 2 * ns) * (self.ssm.d_conv + 1)  # conv
                total += d_in * d                                # out_proj
                total += d_in + 3 * nheads                       # norm/dt/a/D
            else:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            if self.moe is not None and (i % max(self.moe.every_k_layers, 1)
                                         == (self.moe.every_k_layers - 1)):
                total += self.moe.num_experts * 3 * d * self.moe.expert_d_ff
                total += d * self.moe.num_experts  # router
                if self.moe.num_shared:
                    total += 3 * d * self.moe.shared_d_ff
            elif not is_ssm or self.family == "hybrid":
                total += 3 * d * self.d_ff if self.d_ff else 0
            total += 2 * d  # norms
            if enc_dec is not None and i >= enc_dec.enc_layers:
                total += 2 * d * self.num_heads * hd + 2 * d  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        dead = (self.moe.num_experts - self.moe.top_k) * 3 * self.d_model \
            * self.moe.expert_d_ff
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if i % max(self.moe.every_k_layers, 1) == (self.moe.every_k_layers - 1))
        return int(full - dead * n_moe_layers)

    def _layer_is_ssm(self, i: int) -> bool:
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_every:
            return (i % self.attn_every) != (self.attn_every - 1)
        return False

    def layer_is_attn(self, i: int) -> bool:
        return not self._layer_is_ssm(i)

    def layer_window(self, i: int) -> int | None:
        """Per-layer sliding window (None = global attention)."""
        if self.window is None:
            return None
        if self.global_every and (i % self.global_every == self.global_every - 1):
            return None
        return self.window

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        k = max(self.moe.every_k_layers, 1)
        return i % k == (k - 1)
