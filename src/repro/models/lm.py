"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

Layer stacks are *scanned* (stacked parameter pytrees + ``jax.lax.scan``)
so 64-layer models compile fast and remat policies apply per block.
Heterogeneity is handled without unrolling:

  * gemma3's 5:1 local:global pattern → the per-layer window is **data**
    (an int32 array scanned alongside the layer params), keeping one
    homogeneous scan;
  * jamba's [7×mamba + 1×attn] × 4 with MoE on odd layers → scan over
    *groups*: the group structure is identical, so group params stack.

Caches: attention layers use (k, v) ring-written by ``cache_pos``; SSM
layers carry (conv_state, h). ``init_cache`` builds the right pytree per
family; prefill fills it in one forward.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import layers, moe as moe_lib, ssd as ssd_lib
from .config import ArchConfig
from .params import P, init_params
from ..sharding.activation import constrain, batch_axes


class LMOut(NamedTuple):
    logits: jax.Array
    cache: Any
    aux_loss: jax.Array


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------
def _attn_layer_defs(cfg: ArchConfig) -> dict:
    d = {"ln1": layers.rmsnorm_defs(cfg.d_model),
         "attn": layers.attention_defs(cfg)}
    d.update(_ffn_defs(cfg, is_moe=cfg.moe is not None
             and cfg.moe.every_k_layers == 1))
    return d


def _ffn_defs(cfg: ArchConfig, is_moe: bool) -> dict:
    if is_moe:
        return {"ln2": layers.rmsnorm_defs(cfg.d_model),
                "moe": moe_lib.moe_defs(cfg.d_model, cfg.moe)}
    if cfg.d_ff:
        return {"ln2": layers.rmsnorm_defs(cfg.d_model),
                "mlp": layers.mlp_defs(cfg.d_model, cfg.d_ff)}
    return {}


def _ssm_layer_defs(cfg: ArchConfig, with_ffn: bool, is_moe: bool) -> dict:
    d = {"ln1": layers.rmsnorm_defs(cfg.d_model),
         "ssm": ssd_lib.ssm_defs(cfg.d_model, cfg.ssm)}
    if with_ffn:
        d.update(_ffn_defs(cfg, is_moe))
    return d


def _stack(defs: Any, n: int) -> Any:
    """Prepend a scanned 'layers' dim to every P leaf."""
    return jax.tree_util.tree_map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init,
                    p.scale, p.dtype),
        defs, is_leaf=lambda x: isinstance(x, P))


def _group_defs(cfg: ArchConfig) -> list[dict]:
    """Jamba-style group of ``attn_every`` layers (SSM…SSM, attn last)."""
    out = []
    for i in range(cfg.attn_every):
        is_moe = cfg.layer_is_moe(i)
        if i == cfg.attn_every - 1:
            d = {"ln1": layers.rmsnorm_defs(cfg.d_model),
                 "attn": layers.attention_defs(cfg)}
            d.update(_ffn_defs(cfg, is_moe))
        else:
            d = _ssm_layer_defs(cfg, with_ffn=True, is_moe=is_moe)
        out.append(d)
    return out


def param_defs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    defs: dict = {
        "embed": P((v, d), ("vocab", "embed")),
        "final_norm": layers.rmsnorm_defs(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = P((d, v), ("embed", "vocab"))
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        defs["groups"] = _stack(_group_defs(cfg), n_groups)
    elif cfg.family == "ssm":
        defs["blocks"] = _stack(
            _ssm_layer_defs(cfg, with_ffn=bool(cfg.d_ff),
                            is_moe=False), cfg.num_layers)
    else:  # dense / moe / vlm
        defs["blocks"] = _stack(_attn_layer_defs(cfg), cfg.num_layers)
    return defs


def init(cfg: ArchConfig, key: jax.Array) -> dict:
    return init_params(param_defs(cfg), key)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _window_groups(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_full_groups, group_size, n_tail_local) for window_cache mode."""
    g = cfg.global_every
    n_groups = cfg.num_layers // g
    tail = cfg.num_layers - n_groups * g
    return n_groups, g, tail


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv_shape = (batch, max_len, kvh, hd)
    if cfg.window_cache and cfg.window is not None and cfg.global_every:
        ng, g, tail = _window_groups(cfg)
        w = min(cfg.window, max_len)
        neg = -(1 << 30)
        return {
            # local layers: ring buffers of `window` slots + absolute positions
            "kl": jnp.zeros((ng, g - 1, batch, w, kvh, hd), jnp.bfloat16),
            "vl": jnp.zeros((ng, g - 1, batch, w, kvh, hd), jnp.bfloat16),
            "kpl": jnp.full((ng, g - 1, batch, w), neg, jnp.int32),
            # global layers: full-length caches
            "kg": jnp.zeros((ng, 1) + kv_shape, jnp.bfloat16),
            "vg": jnp.zeros((ng, 1) + kv_shape, jnp.bfloat16),
            # tail local layers (num_layers % global_every)
            "kt": jnp.zeros((tail, batch, w, kvh, hd), jnp.bfloat16),
            "vt": jnp.zeros((tail, batch, w, kvh, hd), jnp.bfloat16),
            "kpt": jnp.full((tail, batch, w), neg, jnp.int32),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        n_ssm = cfg.attn_every - 1
        conv, h = ssd_lib.init_ssm_state(cfg, cfg.ssm, batch)
        return {
            "k": jnp.zeros((n_groups,) + kv_shape, jnp.bfloat16),
            "v": jnp.zeros((n_groups,) + kv_shape, jnp.bfloat16),
            "conv": jnp.zeros((n_groups, n_ssm) + conv.shape, conv.dtype),
            "h": jnp.zeros((n_groups, n_ssm) + h.shape, h.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        conv, h = ssd_lib.init_ssm_state(cfg, cfg.ssm, batch)
        return {
            "conv": jnp.zeros((cfg.num_layers,) + conv.shape, conv.dtype),
            "h": jnp.zeros((cfg.num_layers,) + h.shape, h.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.num_layers,) + kv_shape, jnp.bfloat16),
        "v": jnp.zeros((cfg.num_layers,) + kv_shape, jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }


def _windows_array(cfg: ArchConfig) -> jnp.ndarray:
    return jnp.asarray(
        [cfg.layer_window(i) if cfg.layer_window(i) is not None
         else layers.GLOBAL_WINDOW for i in range(cfg.num_layers)],
        jnp.int32)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def embed_lookup(cfg: ArchConfig, table: jax.Array, tokens: jax.Array
                 ) -> jax.Array:
    """Embedding lookup. "onehot" expresses the lookup as a one-hot matmul —
    the one-hot fuses into the dot, and a vocab-sharded table contracts with
    a psum instead of XLA's replicate-the-table sharded-gather fallback."""
    if cfg.embed_impl == "onehot":
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=jnp.bfloat16)
        return oh @ table.astype(jnp.bfloat16)
    return table.astype(jnp.bfloat16)[tokens]


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "block": save only carries


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            positions: jax.Array | None = None,
            vision_embeds: jax.Array | None = None,
            mrope_positions: jax.Array | None = None,
            cache: dict | None = None) -> LMOut:
    """Token forward. tokens: (B, S) int32.

    With ``cache``: writes K/V (or SSM state) at ``cache['pos']`` and
    returns the updated cache — S == 1 is the decode step, S > 1 prefill.
    """
    b, s = tokens.shape
    h = embed_lookup(cfg, params["embed"], tokens)
    if vision_embeds is not None:
        npatch = vision_embeds.shape[1]
        h = jnp.concatenate(
            [vision_embeds.astype(h.dtype), h[:, npatch:]], axis=1)
    base = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    if positions is None:
        positions = base[None, None] + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h = constrain(h, batch_axes(), None, None)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family == "hybrid":
        h, new_cache, aux = _hybrid_stack(cfg, params, h, positions, cache)
    elif cfg.family == "ssm":
        h, new_cache, aux = _ssm_stack(cfg, params, h, positions, cache)
    elif (cfg.window_cache and cache is not None and cfg.window is not None
          and cfg.global_every):
        h, new_cache, aux = _windowed_stack(cfg, params, h, positions, cache)
    else:
        h, new_cache, aux = _attn_stack(cfg, params, h, positions, cache,
                                        mrope_positions)
    aux = aux + aux0

    h = layers.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    logits = constrain(logits, batch_axes(), None,
                       None if "model" in batch_axes() else "model")
    if new_cache is not None and cache is not None:
        new_cache["pos"] = base + s
    return LMOut(logits=logits, cache=new_cache, aux_loss=aux)


# --- homogeneous attention stack (dense / moe / vlm / gemma3) ----------------
def _attn_stack(cfg, params, h, positions, cache, mrope_positions):
    windows = _windows_array(cfg)
    has_cache = cache is not None
    base = cache["pos"] if has_cache else None
    is_moe = cfg.moe is not None and cfg.moe.every_k_layers == 1

    def body(carry, xs):
        h, aux = carry
        if has_cache:
            p, window, kc, vc = xs
        else:
            p, window = xs
            kc = vc = None
        x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
        attn_out, new_kv = layers.attn_block(
            cfg, p["attn"], x, positions, window=window,
            kv_cache=(kc, vc) if has_cache else None,
            cache_pos=base if has_cache else None,
            mrope_positions=mrope_positions)
        h = h + attn_out
        x = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
        if is_moe:
            moe_fn = {"shard_map": moe_lib.moe_block_sharded,
                         "a2a": moe_lib.moe_block_a2a}.get(
                             cfg.moe_impl, moe_lib.moe_block)
            ffn_out, a = moe_fn(cfg.moe, p["moe"], x)
            aux = aux + a
        else:
            ffn_out = layers.mlp_block(p["mlp"], x)
        h = h + ffn_out
        h = constrain(h, batch_axes(), None, None)
        if has_cache:
            return (h, aux), (new_kv[0], new_kv[1])
        return (h, aux), None

    body = _maybe_remat(body, cfg)
    init_carry = (h, jnp.zeros((), jnp.float32))
    if has_cache:
        xs = (params["blocks"], windows, cache["k"], cache["v"])
        (h, aux), (ks, vs) = jax.lax.scan(body, init_carry, xs, unroll=cfg.unroll)
        new_cache = {"k": ks, "v": vs, "pos": cache["pos"]}
    else:
        xs = (params["blocks"], windows)
        (h, aux), _ = jax.lax.scan(body, init_carry, xs, unroll=cfg.unroll)
        new_cache = None
    return h, new_cache, aux


# --- pure SSM stack (mamba2) ---------------------------------------------------
def _ssm_stack(cfg, params, h, positions, cache):
    has_cache = cache is not None
    has_ffn = bool(cfg.d_ff)

    def body(carry, xs):
        h, aux = carry
        if has_cache:
            p, conv, hst = xs
            state = (conv, hst)
        else:
            p, = xs
            state = None
        x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
        out, new_state = ssd_lib.ssm_block(cfg, cfg.ssm, p["ssm"], x, state)
        h = h + out
        if has_ffn:
            h = h + layers.mlp_block(p["mlp"],
                                     layers.rmsnorm(h, p["ln2"], cfg.norm_eps))
        h = constrain(h, batch_axes(), None, None)
        ys = new_state if has_cache else None
        return (h, aux), ys

    body = _maybe_remat(body, cfg)
    init_carry = (h, jnp.zeros((), jnp.float32))
    if has_cache:
        xs = (params["blocks"], cache["conv"], cache["h"])
        (h, aux), (convs, hs) = jax.lax.scan(body, init_carry, xs, unroll=cfg.unroll)
        new_cache = {"conv": convs, "h": hs, "pos": cache["pos"]}
    else:
        (h, aux), _ = jax.lax.scan(body, init_carry, (params["blocks"],), unroll=cfg.unroll)
        new_cache = None
    return h, new_cache, aux


# --- windowed group stack (gemma3 window_cache mode) -------------------------
def _windowed_stack(cfg, params, h, positions, cache):
    """Groups of [ (global_every−1) × local-ring, 1 × global ] layers, plus a
    tail of local layers — ring caches for locals, full cache for globals."""
    ng, g, tail = _window_groups(cfg)
    base = cache["pos"]
    w = cfg.window

    blocks = params["blocks"]
    main = jax.tree_util.tree_map(
        lambda t: t[:ng * g].reshape((ng, g) + t.shape[1:]), blocks)
    tailp = jax.tree_util.tree_map(lambda t: t[ng * g:], blocks)

    def ffn(p, h, aux):
        x = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None and cfg.moe.every_k_layers == 1:
            moe_fn = {"shard_map": moe_lib.moe_block_sharded,
                         "a2a": moe_lib.moe_block_a2a}.get(
                             cfg.moe_impl, moe_lib.moe_block)
            out, a = moe_fn(cfg.moe, p["moe"], x)
            return h + out, aux + a
        return h + layers.mlp_block(p["mlp"], x), aux

    def body(carry, xs):
        h, aux = carry
        gp, kl, vl, kpl, kg, vg = xs
        new_l = {"k": [], "v": [], "p": []}
        for i in range(g):
            p = jax.tree_util.tree_map(lambda t: t[i], gp)
            x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
            if i < g - 1:     # local ring layer
                out, (nk, nv, nkp) = layers.attn_block_ring(
                    cfg, p["attn"], x, positions,
                    (kl[i], vl[i], kpl[i]), base, w)
                new_l["k"].append(nk)
                new_l["v"].append(nv)
                new_l["p"].append(nkp)
            else:             # global layer, full cache
                out, new_kv = layers.attn_block(
                    cfg, p["attn"], x, positions, window=None,
                    kv_cache=(kg[0], vg[0]), cache_pos=base)
            h = h + out
            h, aux = ffn(p, h, aux)
        h = constrain(h, batch_axes(), None, None)
        ys = (jnp.stack(new_l["k"]), jnp.stack(new_l["v"]),
              jnp.stack(new_l["p"]),
              new_kv[0][None], new_kv[1][None])
        return (h, aux), ys

    body = _maybe_remat(body, cfg)
    xs = (main, cache["kl"], cache["vl"], cache["kpl"],
          cache["kg"], cache["vg"])
    (h, aux), (kls, vls, kpls, kgs, vgs) = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), xs, unroll=cfg.unroll)

    kts, vts, kpts = [], [], []
    for i in range(tail):
        p = jax.tree_util.tree_map(lambda t: t[i], tailp)
        x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
        out, (nk, nv, nkp) = layers.attn_block_ring(
            cfg, p["attn"], x, positions,
            (cache["kt"][i], cache["vt"][i], cache["kpt"][i]), base, w)
        kts.append(nk)
        vts.append(nv)
        kpts.append(nkp)
        h = h + out
        h, aux = ffn(p, h, aux)
    h = constrain(h, batch_axes(), None, None)

    new_cache = {
        "kl": kls, "vl": vls, "kpl": kpls, "kg": kgs, "vg": vgs,
        "kt": (jnp.stack(kts) if tail else cache["kt"]),
        "vt": (jnp.stack(vts) if tail else cache["vt"]),
        "kpt": (jnp.stack(kpts) if tail else cache["kpt"]),
        "pos": cache["pos"],
    }
    return h, new_cache, aux


# --- hybrid group stack (jamba) -------------------------------------------------
def _hybrid_stack(cfg, params, h, positions, cache):
    has_cache = cache is not None
    base = cache["pos"] if has_cache else None
    n_ssm = cfg.attn_every - 1

    def body(carry, xs):
        h, aux = carry
        if has_cache:
            gp, kc, vc, convs, hsts = xs
        else:
            gp, = xs
        new_convs, new_hs = [], []
        for i in range(cfg.attn_every):
            p = gp[i]
            is_moe = cfg.layer_is_moe(i)
            x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
            if i < n_ssm:  # SSM sub-layer
                state = (convs[i], hsts[i]) if has_cache else None
                out, new_state = ssd_lib.ssm_block(
                    cfg, cfg.ssm, p["ssm"], x, state)
                if has_cache:
                    new_convs.append(new_state[0])
                    new_hs.append(new_state[1])
            else:          # attention sub-layer
                out, new_kv = layers.attn_block(
                    cfg, p["attn"], x, positions, window=None,
                    kv_cache=(kc, vc) if has_cache else None,
                    cache_pos=base if has_cache else None)
            h = h + out
            x = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
            if is_moe:
                moe_fn = {"shard_map": moe_lib.moe_block_sharded,
                             "a2a": moe_lib.moe_block_a2a}.get(
                                 cfg.moe_impl, moe_lib.moe_block)
                ffn_out, a = moe_fn(cfg.moe, p["moe"], x)
                aux = aux + a
            else:
                ffn_out = layers.mlp_block(p["mlp"], x)
            h = h + ffn_out
        h = constrain(h, batch_axes(), None, None)
        if has_cache:
            ys = (new_kv[0], new_kv[1],
                  jnp.stack(new_convs), jnp.stack(new_hs))
        else:
            ys = None
        return (h, aux), ys

    body = _maybe_remat(body, cfg)
    init_carry = (h, jnp.zeros((), jnp.float32))
    if has_cache:
        xs = (params["groups"], cache["k"], cache["v"],
              cache["conv"], cache["h"])
        (h, aux), (ks, vs, convs, hs) = jax.lax.scan(body, init_carry, xs, unroll=cfg.unroll)
        new_cache = {"k": ks, "v": vs, "conv": convs, "h": hs,
                     "pos": cache["pos"]}
    else:
        (h, aux), _ = jax.lax.scan(body, init_carry, (params["groups"],), unroll=cfg.unroll)
        new_cache = None
    return h, new_cache, aux
