"""Declarative parameter trees with logical sharding axes.

A model is described once as a pytree of :class:`P` leaves (shape + logical
axis names + init). From that single description we derive:

  * ``init_params``  — materialized arrays (deterministic per-leaf keys),
  * ``param_specs``  — ``PartitionSpec`` tree for pjit in/out shardings,
    resolved against a concrete mesh with divisibility fallback (a logical
    axis only binds a mesh axis when the dim is divisible and the mesh axis
    is not already used by an earlier dim — this is what auto-selects EP vs
    expert-TP for MoE weights, and replicates 8-way KV heads on a 16-way
    model axis instead of failing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple              # logical axis name (or None) per dim
    init: str = "normal"     # normal | zeros | ones
    scale: float | None = None   # stddev; default 1/sqrt(fan_in-ish)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Logical axis → preferred mesh axes, in priority order.
DEFAULT_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "embed": ("data",),        # FSDP / ZeRO-3 over the data axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),     # EP when divisible…
    "expert_mlp": ("model",),  # …else expert-TP picks up the axis here
    "ssm_inner": ("model",),
    "layers": (),
    "stage": (),
}


def _leaf_key(root: jax.Array, path) -> jax.Array:
    k = root
    for part in path:
        token = getattr(part, "key", getattr(part, "idx", getattr(part, "name", part)))
        k = jax.random.fold_in(k, abs(hash(str(token))) % (2**31))
    return k


def init_params(defs: Any, key: jax.Array) -> Any:
    """Materialize a pytree of P leaves into arrays."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, P))
    leaves = []
    for path, p in flat:
        assert isinstance(p, P), f"non-P leaf at {path}: {p}"
        k = _leaf_key(key, path)
        if p.init == "zeros":
            arr = jnp.zeros(p.shape, p.dtype)
        elif p.init == "ones":
            arr = jnp.ones(p.shape, p.dtype)
        else:
            # GPT-2-style fixed scale unless overridden; RMSNorm keeps the
            # network well-conditioned regardless of exact fan-in scaling.
            scale = p.scale if p.scale is not None else 0.02
            arr = (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(p.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_specs(defs: Any, mesh, rules: Mapping[str, tuple] | None = None) -> Any:
    """PartitionSpec tree for a P-tree, resolved against ``mesh``
    (``jax.sharding.Mesh`` or ``AbstractMesh`` — only axis sizes are used)."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    axis_sizes = dict(mesh.shape)

    def resolve(p: P) -> PartitionSpec:
        used: set = set()
        entries = []
        for dim, logical in zip(p.shape, p.axes):
            cand = rules.get(logical, ()) if logical else ()
            picked: tuple = ()
            # try full tuple first, then singles
            options = [cand] + [(c,) for c in cand] if len(cand) > 1 else [cand]
            for opt in options:
                if not opt:
                    continue
                size = int(np.prod([axis_sizes[a] for a in opt]))
                if all(a not in used and a in axis_sizes for a in opt) \
                        and dim % size == 0 and size > 1:
                    picked = tuple(opt)
                    break
            used.update(picked)
            if len(picked) == 0:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(picked)
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    return jax.tree_util.tree_map(
        resolve, defs, is_leaf=lambda x: isinstance(x, P))


def shardings_for(defs: Any, mesh, rules=None) -> Any:
    specs = param_specs(defs, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, PartitionSpec))
