"""Whisper-style encoder-decoder backbone.

Per the assignment, the audio frontend (mel → conv downsampling) is a STUB:
``input_specs()`` feeds precomputed frame embeddings (B, S_enc, d_model)
directly. The transformer backbone is real: bidirectional encoder stack,
causal decoder stack with self-attention KV cache + cross-attention over the
encoder output.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ArchConfig
from .params import P, init_params
from .lm import _stack, _maybe_remat
from ..sharding.activation import constrain, batch_axes


class EncDecOut(NamedTuple):
    logits: jax.Array
    cache: Any
    aux_loss: jax.Array


def _enc_layer_defs(cfg: ArchConfig) -> dict:
    return {"ln1": layers.rmsnorm_defs(cfg.d_model),
            "attn": layers.attention_defs(cfg),
            "ln2": layers.rmsnorm_defs(cfg.d_model),
            "mlp": layers.mlp_defs(cfg.d_model, cfg.d_ff)}


def _dec_layer_defs(cfg: ArchConfig) -> dict:
    return {"ln1": layers.rmsnorm_defs(cfg.d_model),
            "attn": layers.attention_defs(cfg),
            "lnx": layers.rmsnorm_defs(cfg.d_model),
            "xattn": layers.attention_defs(cfg),
            "ln2": layers.rmsnorm_defs(cfg.d_model),
            "mlp": layers.mlp_defs(cfg.d_model, cfg.d_ff)}


def param_defs(cfg: ArchConfig) -> dict:
    ed = cfg.encdec
    return {
        "frame_proj": P((cfg.d_model, cfg.d_model), ("embed", None)),  # stub frontend adapter
        "embed": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "enc": _stack(_enc_layer_defs(cfg), ed.enc_layers),
        "dec": _stack(_dec_layer_defs(cfg), ed.dec_layers),
        "enc_norm": layers.rmsnorm_defs(cfg.d_model),
        "final_norm": layers.rmsnorm_defs(cfg.d_model),
        "lm_head": P((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


def init(cfg: ArchConfig, key: jax.Array) -> dict:
    return init_params(param_defs(cfg), key)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int) -> dict:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.encdec.dec_layers
    return {
        "k": jnp.zeros((L, batch, max_len, kvh, hd), jnp.bfloat16),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), jnp.bfloat16),
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }


def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d_model) stub embeddings → encoder states."""
    b, s, _ = frames.shape
    h = frames.astype(jnp.bfloat16) @ params["frame_proj"]
    h = constrain(h, batch_axes(), None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, p):
        h, = carry
        x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
        out, _ = layers.attn_block(cfg, p["attn"], x, positions,
                                   window=None, causal=False)
        h = h + out
        h = h + layers.mlp_block(
            p["mlp"], layers.rmsnorm(h, p["ln2"], cfg.norm_eps))
        h = constrain(h, batch_axes(), None, None)
        return (h,), None

    body = _maybe_remat(body, cfg)
    (h,), _ = jax.lax.scan(body, (h,), params["enc"], unroll=cfg.unroll)
    return layers.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def decode(cfg: ArchConfig, params: dict, tokens: jax.Array,
           enc_out: jax.Array, cache: dict | None = None) -> EncDecOut:
    """Teacher-forced decode (cache=None) or incremental decode (cache)."""
    from .lm import embed_lookup
    b, s = tokens.shape
    h = embed_lookup(cfg, params["embed"], tokens)
    base = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = base[None, None] + jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h = constrain(h, batch_axes(), None, None)
    has_cache = cache is not None

    def body(carry, xs):
        h, = carry
        if has_cache:
            p, kc, vc = xs
        else:
            p, = xs
            kc = vc = None
        x = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
        out, new_kv = layers.attn_block(
            cfg, p["attn"], x, positions, window=None,
            kv_cache=(kc, vc) if has_cache else None,
            cache_pos=base if has_cache else None)
        h = h + out
        x = layers.rmsnorm(h, p["lnx"], cfg.norm_eps)
        h = h + layers.cross_attn_block(cfg, p["xattn"], x, enc_out)
        h = h + layers.mlp_block(
            p["mlp"], layers.rmsnorm(h, p["ln2"], cfg.norm_eps))
        h = constrain(h, batch_axes(), None, None)
        ys = (new_kv[0], new_kv[1]) if has_cache else None
        return (h,), ys

    body = _maybe_remat(body, cfg)
    if has_cache:
        xs = (params["dec"], cache["k"], cache["v"])
        (h,), (ks, vs) = jax.lax.scan(body, (h,), xs, unroll=cfg.unroll)
        new_cache = {"k": ks, "v": vs, "enc_out": enc_out, "pos": base + s}
    else:
        (h,), _ = jax.lax.scan(body, (h,), (params["dec"],), unroll=cfg.unroll)
        new_cache = None
    h = layers.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype))
    logits = constrain(logits, batch_axes(), None,
                       None if "model" in batch_axes() else "model")
    return EncDecOut(logits=logits, cache=new_cache,
                     aux_loss=jnp.zeros((), jnp.float32))


def forward(cfg: ArchConfig, params: dict, frames: jax.Array,
            tokens: jax.Array) -> EncDecOut:
    """Training forward: encode frames, teacher-force decode tokens."""
    enc_out = encode(cfg, params, frames)
    return decode(cfg, params, tokens, enc_out, cache=None)
