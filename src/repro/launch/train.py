"""Production training driver.

Fault-tolerance model (DESIGN.md §2): training is segmented; every segment
boundary asynchronously materializes TrainState into the content-addressed
store. A restarted job (``--resume``) restores the newest checkpoint —
re-sharded onto whatever mesh the new job has (elastic) — and the
deterministic batcher (pure function of (seed, step)) replays the exact
data stream. A per-step watchdog flags stragglers via z-score on step time.

CPU-friendly: ``--reduced`` runs the same code path on the smoke config.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from .. import configs
from ..checkpoint import CheckpointManager
from ..core.store import Store
from ..data import synth
from ..data.pipeline import TokenBatcher
from ..models.params import param_specs
from ..models import registry
from ..sharding import rules as rules_lib
from ..train import steps
from .mesh import make_local_mesh, make_production_mesh


class Watchdog:
    """Straggler/step-time anomaly detection."""

    def __init__(self, z_thresh: float = 4.0):
        self.times: list[float] = []
        self.z = z_thresh

    def observe(self, dt: float) -> str | None:
        self.times.append(dt)
        if len(self.times) < 10:
            return None
        mu = float(np.mean(self.times[-50:-1]))
        sd = float(np.std(self.times[-50:-1])) + 1e-9
        if (dt - mu) / sd > self.z:
            return (f"straggler suspected: step took {dt:.3f}s "
                    f"(mean {mu:.3f}s, z={(dt - mu) / sd:.1f})")
        return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="helix100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--segment-steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="results/train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    tokens = synth.lm_tokens(args.seed, max(2_000_000,
                                            args.batch * (args.seq + 1) * 4),
                             cfg.vocab_size)
    batcher = TokenBatcher(tokens, args.batch, args.seq, seed=args.seed)

    store = Store(f"{args.workdir}/store")
    ckpt = CheckpointManager(store, run_name=f"{cfg.name}-s{args.seed}")

    specs = param_specs(registry.param_defs(cfg), mesh,
                        rules_lib.TRAIN_2D)
    pshard = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))

    with mesh:
        start_step = 0
        if args.resume:
            latest = ckpt.latest_step()
            if latest is not None:
                def shard_for(i, shape, dtype, _fs=None):
                    return None   # restore to host, device_put below
                state = ckpt.restore(latest)
                state = jax.device_put(state, steps.TrainState(
                    params=pshard,
                    opt=steps.adamw.AdamWState(
                        m=pshard, v=pshard,
                        step=jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec()))))
                start_step = latest
                print(f"resumed from step {latest} "
                      f"(elastic restore onto {dict(mesh.shape)})")
        if start_step == 0:
            state = steps.init_train_state(cfg, jax.random.PRNGKey(args.seed))
            state = jax.device_put(state, steps.TrainState(
                params=pshard,
                opt=steps.adamw.AdamWState(
                    m=pshard, v=pshard,
                    step=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()))))

        jstep = jax.jit(
            lambda st, b: steps.train_step(
                cfg, st, b, peak_lr=args.lr, warmup_steps=20,
                total_steps=args.steps),
            donate_argnums=(0,))

        dog = Watchdog()
        losses = []
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in batcher.batch_at(step).items()}
            t0 = time.perf_counter()
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            warn = dog.observe(dt)
            if warn:
                print(f"[watchdog] {warn}")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"{dt:.3f}s/step", flush=True)
            if (step + 1) % args.segment_steps == 0:
                ckpt.save(step + 1, state)       # async materialization
        ckpt.wait()
        print(f"done: loss {losses[0]:.3f} → {losses[-1]:.3f} "
              f"({args.steps - start_step} steps)")


if __name__ == "__main__":
    main()
