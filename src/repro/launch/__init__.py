# Launch layer: production mesh builders, the multi-pod dry-run driver,
# and the train/serve entry points. NOTE: dryrun must be executed as
# ``python -m repro.launch.dryrun`` (it force-sets 512 host devices before
# importing jax); importing this package does NOT touch device state.
from . import mesh, shapes

__all__ = ["mesh", "shapes"]
