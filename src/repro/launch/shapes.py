"""Assigned input shapes × per-arch input specs + sharding policies.

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins for
every input of the lowered step (weak-type-correct, no allocation), and
``shardings(cfg, shape_name, mesh)`` the matching NamedSharding pytrees.

Sharding policy summary (see DESIGN.md §5):
  train    params+opt 2D (FSDP over data × TP over model); batch over
           (pod, data)
  prefill  params TP; batch over (pod, data)
  decode   params TP; batch over (pod, data); KV-cache *sequence* over
           model (32k·128 caches don't fit otherwise)
  long     batch=1 → KV-cache sequence over (data, model); SSM state
           replicated (it is O(1) per sequence)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..models import registry
from ..models.config import ArchConfig
from ..models.params import param_specs
from ..sharding import rules as rules_lib
from ..train import steps


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# long_500k policy (DESIGN.md §4): only sub-quadratic families.
LONG_OK_FAMILIES = ("ssm", "hybrid")


def long_ok(cfg: ArchConfig) -> bool:
    return cfg.family in LONG_OK_FAMILIES or cfg.window is not None


def cells(cfg: ArchConfig) -> list[str]:
    """The assigned (runnable) shapes for this arch."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if long_ok(cfg):
        out.append("long_500k")
    return out


# ---------------------------------------------------------------------------
# input ShapeDtypeStructs
# ---------------------------------------------------------------------------
_VLM_PATCHES = 1024          # stubbed vision prefix length (train/prefill)
_AUDIO_DEC_LEN = 448         # whisper decoder target length


def _batch_sds(cfg: ArchConfig, sh: ShapeSpec) -> dict:
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    S = jax.ShapeDtypeStruct
    b, s = sh.batch, sh.seq
    batch: dict = {}
    if cfg.family == "audio":
        batch["frames"] = S((b, s, cfg.d_model), bf16)
        batch["tokens"] = S((b, _AUDIO_DEC_LEN), i32)
        return batch
    batch["tokens"] = S((b, s), i32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = S((b, _VLM_PATCHES, cfg.d_model), bf16)
        batch["mrope_positions"] = S((3, b, s), i32)
    return batch


def input_specs(cfg: ArchConfig, shape_name: str) -> tuple:
    """ShapeDtypeStruct stand-ins for the step's arguments."""
    sh = SHAPES[shape_name]
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if sh.kind == "train":
        state = jax.eval_shape(
            lambda k: steps.init_train_state(cfg, k), key_sds)
        return (state, _batch_sds(cfg, sh))
    params = jax.eval_shape(lambda k: registry.init(cfg, k), key_sds)
    if sh.kind == "prefill":
        return (params, _batch_sds(cfg, sh))
    # decode: one new token against a seq-sized cache
    cache = jax.eval_shape(
        lambda: registry.init_cache(cfg, sh.batch, sh.seq))
    token = jax.ShapeDtypeStruct((sh.batch, 1), jnp.int32)
    return (params, token, cache)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------
def _filter_spec(shape: tuple, entries: list, mesh) -> PartitionSpec:
    """Drop axes that don't exist / don't divide."""
    sizes = dict(mesh.shape)
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in sizes)
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and total > 1 and dim % total == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return PartitionSpec(*out)


def _named(mesh, shape, entries):
    return NamedSharding(mesh, _filter_spec(shape, entries, mesh))


def _batch_shardings(cfg: ArchConfig, sh: ShapeSpec, mesh, batch_sds: dict,
                     bd: tuple = ("pod", "data")) -> dict:
    out = {}
    for k, sds in batch_sds.items():
        if k == "mrope_positions":
            out[k] = _named(mesh, sds.shape, [None, bd, None])
        else:
            out[k] = _named(mesh, sds.shape,
                            [bd] + [None] * (len(sds.shape) - 1))
    return out


def _params_shardings(cfg: ArchConfig, mesh, params_sds, ruleset: dict):
    specs = param_specs(registry.param_defs(cfg), mesh, ruleset)
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs,
        is_leaf=lambda s: isinstance(s, PartitionSpec))


def _cache_shardings(cfg: ArchConfig, sh: ShapeSpec, mesh, cache_sds):
    """KV cache: seq over model (decode_32k) or (data, model) (long_500k,
    batch=1); batch over (pod, data); SSM states: batch over (pod, data)."""
    long_ctx = sh.batch == 1
    bd = ("pod", "data")
    seq_axes = ("data", "model") if long_ctx else ("model",)

    def spec_for(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(sds.shape)
        if name in ("kg", "vg"):      # (G, 1, B, S, KV, hd) global layers
            return [None, None, bd, seq_axes, None, None]
        if name in ("kl", "vl"):      # (G, g-1, B, W, KV, hd) ring buffers
            return [None, None, bd, None, None, None]
        if name == "kpl":
            return [None, None, bd, None]
        if name in ("kt", "vt"):      # (T, B, W, KV, hd)
            return [None, bd, None, None, None]
        if name == "kpt":
            return [None, bd, None]
        if name in ("k", "v"):
            if nd == 5:   # (L, B, S, KV, hd)
                return [None, bd, seq_axes, None, None]
            return [bd, seq_axes, None, None]
        if name == "conv":    # (L[, n_ssm], B, K-1, C)
            return [None] * (nd - 3) + [bd, None, ("model",)]
        if name == "h":       # (L[, n_ssm], B, H, P, N)
            return [None] * (nd - 4) + [bd, None, None, None]
        if name == "enc_out":  # (B, S_enc, D)
            return [bd, None, None]
        return [None] * nd
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_sds)
    out = [_named(mesh, sds.shape, spec_for(path, sds))
           for path, sds in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# the jit-able step per cell
# ---------------------------------------------------------------------------
def build_step(cfg: ArchConfig, shape_name: str, mesh,
               ruleset_name: str | None = None):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate) for
    jitting one (arch × shape) cell on ``mesh``."""
    sh = SHAPES[shape_name]
    args = input_specs(cfg, shape_name)
    if sh.kind == "train":
        rname = ruleset_name or cfg.train_ruleset or "train_2d"
        ruleset = rules_lib.RULESETS[rname]
        bd = rules_lib.BATCH_AXES_BY_RULESET.get(rname, ("pod", "data"))
        state_sds, batch_sds = args
        pshard = _params_shardings(cfg, mesh, state_sds.params, ruleset)
        state_shard = steps.TrainState(
            params=pshard,
            opt=steps.adamw.AdamWState(
                m=pshard, v=pshard,
                step=NamedSharding(mesh, PartitionSpec())))
        in_shardings = (state_shard,
                        _batch_shardings(cfg, sh, mesh, batch_sds, bd=bd))
        out_shardings = (state_shard, None)

        def fn(state, batch):
            from ..sharding.activation import use_batch_axes
            with use_batch_axes(bd):
                return steps.train_step(cfg, state, batch)
        return fn, args, in_shardings, out_shardings, (0,)
    ruleset = rules_lib.RULESETS[ruleset_name or "serve"]
    if sh.kind == "prefill":
        params_sds, batch_sds = args
        pshard = _params_shardings(cfg, mesh, params_sds, ruleset)
        in_shardings = (pshard, _batch_shardings(cfg, sh, mesh, batch_sds))
        cache_sds = jax.eval_shape(
            lambda p, b: steps.prefill_step(cfg, p, b, max_len=sh.seq)[1],
            params_sds, batch_sds)
        out_shardings = (None, _cache_shardings(cfg, sh, mesh, cache_sds))
        fn = lambda p, b: steps.prefill_step(cfg, p, b, max_len=sh.seq)
        return fn, args, in_shardings, out_shardings, ()
    # decode
    params_sds, token_sds, cache_sds = args
    pshard = _params_shardings(cfg, mesh, params_sds, ruleset)
    cshard = _cache_shardings(cfg, sh, mesh, cache_sds)
    tshard = _named(mesh, token_sds.shape, [("pod", "data"), None])
    in_shardings = (pshard, tshard, cshard)
    out_shardings = (None, cshard)
    fn = lambda p, t, c: steps.decode_step(cfg, p, t, c)
    return fn, args, in_shardings, out_shardings, (2,)
