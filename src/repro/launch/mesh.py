"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests, which must
see one CPU device while the dry-run sees 512 placeholders.
"""
from __future__ import annotations

import jax


import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run via "
            f"launch/dryrun.py which sets xla_force_host_platform_device_count")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh():
    """Whatever devices exist locally (1 CPU in tests), as (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
