import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from .. import configs                      # noqa: E402
from ..launch import shapes as shapes_lib   # noqa: E402
from ..launch.mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Collective traffic from post-SPMD optimized HLO, per device.

    For each collective we take the largest typed buffer in the result (for
    async -start ops the result tuple holds operand+result; max = the full
    buffer R) and the replica group size g, then derive:

      operand bytes (the spec's §Roofline convention):
        all-gather R/g · g→R? No: operand = R/g; all-reduce = R;
        reduce-scatter = R·g; all-to-all = R; collective-permute = R.
      wire bytes (ring-algorithm estimate actually crossing links):
        all-gather R·(g−1)/g; all-reduce 2R·(g−1)/g; reduce-scatter
        R·(g−1); all-to-all R·(g−1)/g; collective-permute R.
    """
    operand = {k: 0.0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        m = _LINE_RE.search(s)
        if not m:
            continue
        op = m.group(2)
        sizes = [_shape_bytes(d, dims)
                 for d, dims in _SHAPE_RE.findall(m.group(1))]
        r = max(sizes) if sizes else 0
        g = 1
        gm = _GROUPS_RE.search(s)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(s)
            if gl:
                g = len(gl.group(1).split(","))
        g = max(g, 1)
        counts[op] += 1
        if op == "all-gather":
            operand[op] += r / g
            wire[op] += r * (g - 1) / g
        elif op == "all-reduce":
            operand[op] += r
            wire[op] += 2 * r * (g - 1) / g
        elif op == "reduce-scatter":
            operand[op] += r * g
            wire[op] += r * (g - 1)
        elif op == "all-to-all":
            operand[op] += r
            wire[op] += r * (g - 1) / g
        else:  # collective-permute
            operand[op] += r
            wire[op] += r
    return {"operand_bytes": operand, "wire_bytes": wire, "counts": counts}


def _arg_bytes_per_device(args_sds, in_shardings, n_devices: int) -> int:
    leaves_s = jax.tree_util.tree_leaves(
        args_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    total = 0
    flat_shard = jax.tree_util.tree_leaves(
        in_shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
    for sds, sh in zip(leaves_s, flat_shard):
        nbytes = int(np.prod(sds.shape)) * sds.dtype.itemsize
        if sh is not None and hasattr(sh, "num_devices_sharded_over"):
            pass
        if sh is not None and hasattr(sh, "spec"):
            used = 1
            sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
            for entry in sh.spec:
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    used *= sizes[a]
            nbytes //= used
        total += nbytes
    return total


def model_flops(cfg, shape_name: str, sh=None) -> float:
    """Analytic 6·N·D (train) / 2·N·D (inference) model FLOPs, global."""
    sh = sh or shapes_lib.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.batch * sh.seq
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.batch * sh.seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             ruleset: str | None = None, remat: str | None = None,
             grad_accum: int | None = None, attn_impl: str | None = None,
             embed_impl: str | None = None, xent_impl: str | None = None,
             moe_impl: str | None = None, window_cache: bool = False,
             probe: bool = False,
             out_dir: str = "results/dryrun", tag: str = "") -> dict:
    cfg = configs.get(arch)
    overrides = {}
    if remat is not None:
        overrides["remat"] = remat
    if grad_accum is not None:
        overrides["grad_accum"] = grad_accum
    if attn_impl is not None:
        overrides["attn_impl"] = attn_impl
    if embed_impl is not None:
        overrides["embed_impl"] = embed_impl
    if xent_impl is not None:
        overrides["xent_impl"] = xent_impl
    if moe_impl is not None:
        overrides["moe_impl"] = moe_impl
    if window_cache:
        overrides["window_cache"] = True
    accum_scale = 1
    if probe:
        # Cost-accurate probe: XLA's cost_analysis (and the HLO text) count
        # while-loop bodies ONCE, so scanned models under-report. The probe
        # unrolls the layer stack and runs ONE microbatch; roofline scales
        # the per-microbatch terms back up by the real grad_accum.
        overrides["unroll"] = True
        accum_scale = overrides.get("grad_accum", cfg.grad_accum)
        overrides["grad_accum"] = 1
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "ruleset": ruleset, "overrides": overrides, "tag": tag,
        "probe": probe, "accum_scale": accum_scale,
        "ok": False,
    }
    sh0 = shapes_lib.SHAPES[shape_name]
    patched = sh0
    if probe and sh0.kind == "train" and accum_scale > 1:
        # probe one real microbatch; roofline scales terms ×accum_scale
        patched = dataclasses.replace(sh0, batch=sh0.batch // accum_scale)
    t0 = time.perf_counter()
    try:
        shapes_lib.SHAPES[shape_name] = patched
        mesh = make_production_mesh(multi_pod=multi_pod)
        rec["n_devices"] = int(np.prod(mesh.devices.shape))
        fn, args, in_sh, out_sh, donate = shapes_lib.build_step(
            cfg, shape_name, mesh, ruleset_name=ruleset)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1
            if os.environ.get("DRYRUN_VERBOSE"):
                print(compiled.memory_analysis())   # proves it fits
                print(compiled.cost_analysis())     # FLOPs/bytes for roofline
            try:
                ma = compiled.memory_analysis()
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k)) for k in dir(ma)
                    if k.endswith("_in_bytes") and not k.startswith("_")
                } if ma is not None else None
            except Exception as e:  # CPU backend may not support it
                rec["memory_analysis"] = f"unavailable: {e}"
            try:
                ca = compiled.cost_analysis()
                rec["cost_analysis"] = {
                    k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and (
                        "flops" in k or "bytes" in k or "utilization" in k)}
            except Exception as e:
                rec["cost_analysis"] = f"unavailable: {e}"
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes_from_hlo(hlo)
            rec["hlo_bytes"] = len(hlo)
        rec["arg_bytes_per_device"] = _arg_bytes_per_device(
            args, in_sh, rec["n_devices"])
        rec["model_flops_global"] = model_flops(cfg, shape_name, sh=sh0)
        rec["param_count"] = cfg.param_count()
        rec["active_param_count"] = cfg.active_param_count()
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        shapes_lib.SHAPES[shape_name] = sh0
    rec["total_s"] = time.perf_counter() - t0

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ruleset", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--embed-impl", default=None)
    ap.add_argument("--xent-impl", default=None)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--window-cache", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="unrolled, single-microbatch cost probe "
                         "(accurate cost_analysis; see roofline.py)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = configs.ASSIGNED if (args.all or args.arch is None) \
        else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        cfg = configs.get(arch)
        shp = shapes_lib.cells(cfg) if (args.all or args.shape is None) \
            else [args.shape]
        for shape_name in shp:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                suffix = f"_{args.tag}" if args.tag else ""
                path = os.path.join(
                    args.out, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {path}")
                            continue
                rec = run_cell(
                    arch, shape_name, multi_pod=multi, ruleset=args.ruleset,
                    remat=args.remat, grad_accum=args.grad_accum,
                    attn_impl=args.attn_impl, embed_impl=args.embed_impl,
                    xent_impl=args.xent_impl, moe_impl=args.moe_impl,
                    window_cache=args.window_cache,
                    probe=args.probe, out_dir=args.out, tag=args.tag)
                status = "ok" if rec["ok"] else f"FAIL: {rec.get('error')}"
                print(f"[{arch} × {shape_name} × {mesh_name}] {status} "
                      f"(lower {rec.get('lower_s', 0):.1f}s, "
                      f"compile {rec.get('compile_s', 0):.1f}s)", flush=True)


if __name__ == "__main__":
    main()
