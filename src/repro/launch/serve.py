"""Batched serving driver: prefill a batch of prompts, decode greedily.

Demonstrates the serving path (prefill_step/decode_step with KV/SSM caches)
end-to-end on any arch; CPU-friendly with ``--reduced``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from .. import configs
from ..data import synth
from ..models import registry
from ..train import steps
from .mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("use an LM-family arch for serve (enc-dec decode "
                         "is exercised in tests)")
    mesh = make_local_mesh()
    params = registry.init(cfg, jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen_tokens

    toks = synth.lm_tokens(args.seed, args.batch * args.prompt_len + 1,
                           cfg.vocab_size)
    prompts = toks[:args.batch * args.prompt_len].reshape(
        args.batch, args.prompt_len)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, 4, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len, dtype=jnp.int32),
            (3, args.batch, args.prompt_len))

    with mesh:
        prefill = jax.jit(lambda p, b: steps.prefill_step(
            cfg, p, b, max_len=max_len))
        decode = jax.jit(lambda p, t, c: steps.decode_step(cfg, p, t, c),
                         donate_argnums=(2,))

        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out = [jnp.argmax(logits, -1)[:, None]]
        t0 = time.perf_counter()
        for _ in range(args.gen_tokens - 1):
            logits, cache = decode(params, out[-1].astype(jnp.int32), cache)
            out.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
        jax.block_until_ready(out[-1])
        t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out, 1))
    tok_s = args.batch * (args.gen_tokens - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen_tokens}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_decode*1e3:.1f} ms "
          f"({tok_s:.1f} tok/s)")
    print("first sequence:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
