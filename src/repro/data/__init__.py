from . import pipeline, synth, tabular

__all__ = ["pipeline", "synth", "tabular"]
