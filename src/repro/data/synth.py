"""Deterministic synthetic data sources.

Everything is seeded and content-addressable: the same (seed, size) always
produces the same bytes, which is what makes data nodes *equivalent* across
Helix iterations (paper Def. 2 requires inputs to be reproducible).

``lm_tokens`` produces a Zipf-distributed token stream with enough local
structure (bigram template mixing) that a ~100M model's loss visibly drops
within a few hundred steps — used by examples/train_lm.py.
"""
from __future__ import annotations

import numpy as np


def lm_tokens(seed: int, num_tokens: int, vocab_size: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # Zipf-ish unigram distribution.
    ranks = np.arange(1, vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(vocab_size, size=num_tokens, p=probs)
    # Inject deterministic bigram structure: token t is often followed by
    # (a*t + b) mod V — gives the model something learnable.
    a, b = 31, 7
    follow = rng.random(num_tokens) < 0.5
    base[1:] = np.where(follow[1:], (a * base[:-1] + b) % vocab_size,
                        base[1:])
    return base.astype(np.int32)


def census_rows(seed: int, n: int) -> dict[str, np.ndarray]:
    """Synthetic census-income-like table (the paper's running example)."""
    rng = np.random.default_rng(seed)
    age = rng.integers(17, 90, n)
    education = rng.integers(0, 16, n)
    occupation = rng.integers(0, 15, n)
    hours = rng.integers(1, 99, n)
    capital_gain = (rng.pareto(3.0, n) * 1000).astype(np.int64)
    marital = rng.integers(0, 7, n)
    race = rng.integers(0, 5, n)
    sex = rng.integers(0, 2, n)
    # Ground-truth income rule with noise (so LR has signal).
    score = (0.03 * (age - 40) + 0.25 * (education - 8)
             + 0.15 * (occupation % 5) + 0.02 * (hours - 40)
             + 0.0004 * capital_gain + 0.3 * sex
             + rng.normal(0, 1.0, n))
    target = (score > 0.8).astype(np.int32)
    return dict(age=age, education=education, occupation=occupation,
                hours=hours, capital_gain=capital_gain, marital=marital,
                race=race, sex=sex, target=target)


def documents(seed: int, n_docs: int, doc_len: int, vocab: int
              ) -> np.ndarray:
    """Synthetic 'articles' (token matrices) for the genomics/NLP workflows."""
    rng = np.random.default_rng(seed)
    topics = rng.integers(0, 16, n_docs)
    docs = np.empty((n_docs, doc_len), np.int32)
    for i, t in enumerate(topics):
        center = (t * vocab) // 16
        spread = vocab // 8
        docs[i] = (center + rng.integers(0, spread, doc_len)) % vocab
    return docs


def images(seed: int, n: int, side: int = 28) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic MNIST-like images: class = dominant frequency pattern."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n)
    xs = np.linspace(0, 1, side)
    xx, yy = np.meshgrid(xs, xs)
    imgs = np.empty((n, side, side), np.float32)
    for c in range(10):
        idx = labels == c
        k = idx.sum()
        if k == 0:
            continue
        pattern = np.sin(2 * np.pi * (c + 1) * xx) * np.cos(
            2 * np.pi * ((c % 3) + 1) * yy)
        imgs[idx] = pattern + rng.normal(0, 0.3, (k, side, side))
    return imgs.astype(np.float32), labels.astype(np.int32)
