"""Tabular featurization operators (the census workflow's DPR layer).

These mirror the HML extractors in the paper's Fig. 3: column extractors,
learned discretization (bucket boundaries from data — a *learned* DPR
function in the paper's taxonomy), one-hot encoding, interaction features,
and the example-assembly synthesizer that concatenates feature vectors and
records per-extractor provenance (used for data-driven pruning §5.4).
"""
from __future__ import annotations

import numpy as np


def column(rows: dict, name: str) -> np.ndarray:
    return np.asarray(rows[name])


def bucketize(values: np.ndarray, n_buckets: int) -> np.ndarray:
    """Learned discretizer: quantile boundaries estimated from the data."""
    qs = np.quantile(values, np.linspace(0, 1, n_buckets + 1)[1:-1])
    return np.digitize(values, qs).astype(np.int32)


def one_hot(values: np.ndarray, depth: int) -> np.ndarray:
    out = np.zeros((len(values), depth), np.float32)
    out[np.arange(len(values)), np.clip(values, 0, depth - 1)] = 1.0
    return out


def interact(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Interaction feature: outer product of two one-hot blocks."""
    n = len(a)
    return (a[:, :, None] * b[:, None, :]).reshape(n, -1)


def standardize(values: np.ndarray) -> np.ndarray:
    v = values.astype(np.float32)
    return ((v - v.mean()) / (v.std() + 1e-9))[:, None]


def assemble(feature_blocks: dict[str, np.ndarray]
             ) -> tuple[np.ndarray, dict[str, list[int]]]:
    """Synthesizer: concatenate blocks into FVs + provenance (extractor →
    feature column indices)."""
    mats, provenance, start = [], {}, 0
    for name in sorted(feature_blocks):
        m = feature_blocks[name]
        if m.ndim == 1:
            m = m[:, None]
        mats.append(m.astype(np.float32))
        provenance[name] = list(range(start, start + m.shape[1]))
        start += m.shape[1]
    return np.concatenate(mats, axis=1), provenance
