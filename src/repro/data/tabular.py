"""Tabular featurization operators (the census workflow's DPR layer).

These mirror the HML extractors in the paper's Fig. 3: column extractors,
learned discretization (bucket boundaries from data — a *learned* DPR
function in the paper's taxonomy), one-hot encoding, interaction features,
and the example-assembly synthesizer that concatenates feature vectors and
records per-extractor provenance (used for data-driven pruning §5.4).

Incremental-capability notes (chunks.py): ``one_hot``, ``interact`` and
``fixed_bucketize`` are row-local — safe to declare ``incremental="map"``
on the nodes that wrap them. ``bucketize`` (quantile boundaries *learned
from the whole column*) and ``standardize`` (global mean/std) are NOT
maps: their output for row r depends on every other row, so the nodes
wrapping them must stay opaque (whole-recompute on any append).

The ``census_chunk_descriptors`` / ``load_census_chunks`` pair models an
append-mostly table for chunked sources: each descriptor is a stable
``(seed, n_rows)`` identity, a daily append appends one descriptor, and
the loader generates one column-dict per descriptor — so a chunked
``Workflow.source`` keeps its prefix chunk signatures across appends.
"""
from __future__ import annotations

import numpy as np


def column(rows: dict, name: str) -> np.ndarray:
    return np.asarray(rows[name])


def bucketize(values: np.ndarray, n_buckets: int) -> np.ndarray:
    """Learned discretizer: quantile boundaries estimated from the data."""
    qs = np.quantile(values, np.linspace(0, 1, n_buckets + 1)[1:-1])
    return np.digitize(values, qs).astype(np.int32)


def one_hot(values: np.ndarray, depth: int) -> np.ndarray:
    out = np.zeros((len(values), depth), np.float32)
    out[np.arange(len(values)), np.clip(values, 0, depth - 1)] = 1.0
    return out


def interact(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Interaction feature: outer product of two one-hot blocks."""
    n = len(a)
    return (a[:, :, None] * b[:, None, :]).reshape(n, -1)


def fixed_bucketize(values: np.ndarray, edges) -> np.ndarray:
    """Row-local discretizer: *fixed* bin edges, so (unlike ``bucketize``)
    each row's bucket is independent of the rest of the column — map-safe
    for chunked execution."""
    return np.digitize(values, np.asarray(edges)).astype(np.int32)


def census_chunk_descriptors(seed: int, n_chunks: int,
                             rows_per_chunk: int) -> list[tuple[int, int]]:
    """Stable per-chunk identities for an append-mostly census table.

    Descriptor ``i`` is ``(seed + i, rows_per_chunk)``; appending a day's
    batch means appending one descriptor, which leaves every existing
    descriptor — and therefore every existing chunk signature — intact."""
    return [(seed + i, rows_per_chunk) for i in range(n_chunks)]


def load_census_chunks(descriptors) -> list[dict]:
    """Source fn for ``Workflow.source(..., chunks=descriptors)``: one
    synthetic census column-dict per descriptor (deterministic per
    descriptor, so a regenerated chunk is bit-identical to its cached
    copy)."""
    from . import synth
    return [synth.census_rows(s, n) for s, n in descriptors]


def standardize(values: np.ndarray) -> np.ndarray:
    v = values.astype(np.float32)
    return ((v - v.mean()) / (v.std() + 1e-9))[:, None]


def assemble(feature_blocks: dict[str, np.ndarray]
             ) -> tuple[np.ndarray, dict[str, list[int]]]:
    """Synthesizer: concatenate blocks into FVs + provenance (extractor →
    feature column indices)."""
    mats, provenance, start = [], {}, 0
    for name in sorted(feature_blocks):
        m = feature_blocks[name]
        if m.ndim == 1:
            m = m[:, None]
        mats.append(m.astype(np.float32))
        provenance[name] = list(range(start, start + m.shape[1]))
        start += m.shape[1]
    return np.concatenate(mats, axis=1), provenance
