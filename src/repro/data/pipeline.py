"""Sharded, deterministic batch pipeline.

Determinism contract (fault tolerance): batch ``i`` of run ``seed`` is a pure
function of ``(seed, i)`` — any restarted or re-scaled job reproduces the
exact token stream, so a restored checkpoint continues on the *same* data
order. That is what lets Helix treat training segments as equivalent nodes.
"""
from __future__ import annotations

import numpy as np

import jax


class TokenBatcher:
    def __init__(self, tokens: np.ndarray, batch: int, seq: int, seed: int = 0):
        self.tokens = tokens
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.n_windows = len(tokens) // (seq + 1)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.n_windows, self.batch)
        starts = idx * (self.seq + 1)
        rows = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32)}


def device_put_batch(batch: dict, shardings: dict | None = None) -> dict:
    if shardings is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings.get(k)) for k, v in batch.items()}
