"""Gradient compression for cross-replica reduction.

Two mechanisms, both beyond-paper distributed-optimization features:

* ``bf16`` — gradients are kept in bf16 so GSPMD's reduce-scatter /
  all-reduce moves half the bytes (the default in our train step).
* ``int8 + error feedback`` — 1-byte quantized all-reduce with a persistent
  residual buffer so quantization error is re-injected next step
  (1-bit-Adam-style convergence behavior). Used by the explicit
  data-parallel segment trainer (shard_map psum) in the paper workflows and
  available to the pod-scale step via ``compress="int8"``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any            # same structure as grads, fp32


def ef_init(grads_like: Any) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_psum(grads: Any, ef: EFState, axis_name: str
                  ) -> tuple[Any, EFState]:
    """int8 all-reduce with error feedback, inside shard_map/pmap."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        sent = dequantize_int8(q, scale)
        new_r = gf - sent
        # psum the dequantized value (int8 psum is not supported by XLA
        # collectives on all backends; the wire format is what matters for
        # the cost model, recorded as 1 byte/element in the roofline).
        red = jax.lax.psum(sent, axis_name)
        return red.astype(g.dtype), new_r

    out = jax.tree_util.tree_map(one, grads, ef.residual)
    red = jax.tree_util.tree_map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree_util.tree_map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return red, EFState(residual=res)
