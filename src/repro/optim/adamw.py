"""AdamW with fp32 moments over (possibly bf16) params.

Optimizer state shards exactly like the params (ZeRO-3 when params are
FSDP-sharded over the data axis): the state tree mirrors the param tree, so
``param_specs`` applies verbatim.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32))


def update(params: Any, grads: Any, state: AdamWState, *,
           lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1) -> tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1 - b1 ** t
    c2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and p.ndim >= 2:   # no decay on norms/biases
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, step=step)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm
