from . import adamw, compress, schedules
from .adamw import AdamWState, clip_by_global_norm, global_norm

__all__ = ["adamw", "compress", "schedules", "AdamWState",
           "clip_by_global_norm", "global_norm"]
