"""Checkpointing as Helix materialization.

A training run *is* a Helix workflow whose segment nodes are N-step chunks;
this manager is a thin convenience layer for the launcher: it keys train
state by (run_name, step) signatures in the same content-addressed store,
saves asynchronously off the critical path, and restores with resharding
onto whatever mesh the restarted job has (elastic restart).
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable


from ..core.store import Store


def _sig(run_name: str, step: int) -> str:
    return hashlib.sha256(f"ckpt:{run_name}:{step}".encode()).hexdigest()


class CheckpointManager:
    def __init__(self, store: Store, run_name: str):
        self.store = store
        self.run_name = run_name
        self._pending = []

    def save(self, step: int, state: Any, async_: bool = True) -> None:
        sig = _sig(self.run_name, step)
        name = f"{self.run_name}/step{step}"
        if async_:
            self._pending.append(self.store.save_async(sig, name, state))
        else:
            self.store.save(sig, name, state)

    def wait(self) -> None:
        for th in self._pending:
            th.join()
        self._pending.clear()

    def latest_step(self) -> int | None:
        steps = [int(m["name"].rsplit("step", 1)[1])
                 for m in self.store.entries().values()
                 if m["name"].startswith(self.run_name + "/step")]
        return max(steps) if steps else None

    def restore(self, step: int,
                sharding_for_leaf: Callable | None = None) -> Any:
        value, _ = self.store.load(_sig(self.run_name, step),
                                   sharding_for_leaf=sharding_for_leaf)
        return value
