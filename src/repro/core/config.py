"""Layered configuration for the Helix engines (ISSUE 7 API redesign).

Five PRs of growth left ``IterativeSession``, ``SessionServer`` and
``run_sweep`` each carrying 15–20 overlapping keyword arguments. This
module collapses that sprawl into three small frozen dataclasses, layered
by concern:

* :class:`EngineConfig` — how work *executes*: materialization policy,
  executor width and prefetch, async materialization, OMP's amortization
  horizon, dispatch schedule, session slots, fleet dedupe.
* :class:`StoreConfig` — what is *kept*: the storage budget, eviction
  mode, the remote tier, ledger sharing, stale purging, remote GC.
* :class:`ResilienceConfig` — how failures and waits are *bounded*:
  dedupe lease waits, admission-queue bounds, job timeouts, remote
  retry/backoff, fault injection, client RPC timeouts.

Every constructor that used to take the loose kwargs now accepts
``engine=`` / ``storage=`` / ``resilience=`` instances. The old kwargs
keep working through a deprecation shim — :func:`resolve` maps them onto
the dataclasses and warns once per kwarg name per process — so no
existing call site breaks while new code writes configs.

Context-dependent defaults: a handful of knobs have *different* sane
defaults per call site (a standalone session does not dedupe in-flight
work; a server always does). Those fields default to ``None`` here,
meaning "use the call site's historical default"; passing an explicit
value always wins. Everything else has one unified default.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Mapping

from .omp import Policy


class _Unset:
    """Sentinel distinguishing "kwarg not passed" from an explicit None."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<unset>"


#: Default value of every deprecated legacy kwarg: lets the shim tell an
#: explicitly passed value (even ``None``) from an omitted one.
UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """How work executes: the engine-level knobs.

    ``policy``
        OMP materialization policy (OPT / ALWAYS / NEVER).
    ``max_workers`` / ``prefetch_depth`` / ``async_materialization``
        The pipelined executor: worker-pool width, LOAD-prefetch bound,
        and whether materialization writes go through the store's async
        writer queue.
    ``horizon``
        Static amortization floor for OMP. ``None`` (default) means 1.0
        for a standalone session; under a server's ``"prefix"`` schedule
        the live multiplicity map supersedes it anyway.
    ``schedule``
        Server dispatch policy: ``"prefix"`` (shared-prefix-first),
        ``"fifo"`` (arrival order, the PR 2 baseline), or ``"fair"``
        (weighted fair share across tenants, prefix-first within each —
        requires the server's ``tenants=`` table for the weights).
    ``n_sessions``
        Concurrent session slots. ``None`` = call-site default (4 for a
        server, all variants for a sweep).
    ``pool_workers``
        Size of the process-wide shared executor pool (``None`` = sized
        from ``n_sessions``/``max_workers``).
    ``share_nondet``
        Pin one nonce map so identical nondeterministic operators are
        shared. ``None`` = call-site default (False for a standalone
        session, True for server/sweep).
    ``dedupe_inflight``
        Fleet compute-once protocol (per-signature compute leases).
        ``None`` = call-site default (False standalone, True fleet).
    """

    policy: Policy = Policy.OPT
    max_workers: int = 1
    prefetch_depth: int = 4
    async_materialization: bool = False
    horizon: float | None = None
    schedule: str = "prefix"
    n_sessions: int | None = None
    pool_workers: int | None = None
    share_nondet: bool | None = None
    dedupe_inflight: bool | None = None


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """What is kept: storage budget, eviction, and the remote tier.

    ``budget_bytes``
        Storage budget for materializations (``inf`` = unbounded).
    ``evict_to_admit``
        Benefit-weighted eviction when a materialization does not fit
        (False = refuse-on-exhausted).
    ``remote``
        Fleet-shared remote tier: a ``RemoteStore``, an ``ObjectStore``
        backend, or a filesystem path (shared-mount reference).
    ``shared_budget``
        Enforce the budget against the fleet's shared on-disk ledger.
        ``None`` = call-site default (False standalone; a server always
        shares).
    ``purge_stale``
        The paper's §6.6 purge of prior materializations of original
        operators. ``None`` = call-site default (True standalone, False
        for fleet drivers where sibling variants are not stale).
    ``gc_interval`` / ``gc_min_age``
        Remote-tier orphan GC cadence and safety age gate
        (``gc_interval=None`` = 900 s when a remote is attached).
    ``mem_budget_bytes``
        Host-RAM budget for the store's memory tier (memtier.py): a
        bounded process-local cache of materialized values served
        zero-copy in front of the disk tier. 0 disables the tier.
    ``mem_writeback``
        Write-back mode: saves land memory-only and spill to disk at
        demotion (`mem_flush` is the durability barrier). Off by
        default — write-through keeps every value crash-durable.
    """

    budget_bytes: float = float("inf")
    evict_to_admit: bool = True
    remote: Any = None
    shared_budget: bool | None = None
    purge_stale: bool | None = None
    gc_interval: float | None = None
    gc_min_age: float = 3600.0
    mem_budget_bytes: float = 256e6
    mem_writeback: bool = False


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """How failures and waits are bounded.

    ``dedupe_wait_seconds``
        Upper bound on waiting for another session's compute lease
        before falling back to computing locally. ``None`` = call-site
        default (600 s standalone, 3600 s fleet).
    ``max_queue`` / ``busy_retry_after``
        Bounded admission: queued submissions beyond ``max_queue`` get a
        ``busy`` response carrying the retry hint (``None`` =
        unbounded).
    ``job_timeout``
        Default per-job running-time bound; expiry fires the job's
        cooperative cancel flag (``None`` = unbounded).
    ``remote_max_retries`` / ``remote_retry_backoff``
        Transient remote-backend errors are retried in place with
        exponential backoff + jitter. Applied when the session/server
        *constructs* its remote tier from a path or backend (an injected
        ``RemoteStore`` keeps its own).
    ``faults``
        A :class:`~repro.core.faults.FaultPlan` threaded into a remote
        tier constructed here (tests / chaos drills only).
    ``rpc_timeout`` / ``busy_retries``
        Client-side: per-RPC socket timeout (arms reconnect-on-error)
        and automatic retries of a ``busy`` submit.
    """

    dedupe_wait_seconds: float | None = None
    max_queue: int | None = None
    busy_retry_after: float = 0.5
    job_timeout: float | None = None
    remote_max_retries: int = 3
    remote_retry_backoff: float = 0.05
    faults: Any = None
    rpc_timeout: float | None = None
    busy_retries: int = 8


# Legacy kwarg names that have already warned this process: the shim
# warns once per name, not once per call, so a sweep constructing K
# sessions does not emit K identical warnings.
_WARNED: set[str] = set()


def reset_legacy_warnings() -> None:
    """Forget which deprecated kwargs have warned (test isolation)."""
    _WARNED.clear()


def _warn_once(owner: str, kwarg: str, cls: type, field: str) -> None:
    if kwarg in _WARNED:
        return
    _WARNED.add(kwarg)
    warnings.warn(
        f"{owner}({kwarg}=...) is deprecated; pass "
        f"{cls.__name__}(`{field}=...`) via the config parameters instead "
        f"(see repro.core.config)",
        DeprecationWarning, stacklevel=4)


def resolve(owner: str, cls: type, config: Any,
            site_defaults: Mapping[str, Any] | None = None,
            legacy: Mapping[str, tuple[str, Any]] | None = None) -> Any:
    """Resolve one config group for one constructor call.

    ``config`` is the user-passed instance (or None → ``cls()``);
    ``site_defaults`` fills fields still at their ``None`` "call-site
    default" sentinel; ``legacy`` maps each deprecated kwarg name to
    ``(field, passed_value)`` — values that are not :data:`UNSET`
    override the config (warning once per kwarg name). Returns a fully
    resolved frozen instance.
    """
    if config is None:
        config = cls()
    elif not isinstance(config, cls):
        raise TypeError(
            f"{owner} expected {cls.__name__}, got {type(config).__name__}")
    updates: dict[str, Any] = {}
    for field, default in (site_defaults or {}).items():
        if getattr(config, field) is None:
            updates[field] = default
    for kwarg, (field, value) in (legacy or {}).items():
        if value is UNSET:
            continue
        _warn_once(owner, kwarg, cls, field)
        updates[field] = value
    return dataclasses.replace(config, **updates) if updates else config
