"""Change tracking via recursive signatures (paper §4.2, Defs. 2-3).

The paper's equivalence test: a node is equivalent across iterations iff its
own declaration is unchanged *and* all ancestors are equivalent. We realize
this with a content signature computed bottom-up:

    sig(n) = H(name, kind, version, [sig(p) for p in parents])

Two nodes with equal signatures are *representationally equivalent* in the
paper's sense (conservative: false positives on changes are possible — e.g.
``a+b`` vs ``b+a`` get different signatures — but false negatives are not,
which is what Theorem 1 requires for correctness).

Nondeterministic nodes get a fresh nonce mixed into their signature at every
compilation, so they are never equivalent to any prior run (paper's MNIST
workflow relies on this).
"""
from __future__ import annotations

import hashlib
import uuid

from .dag import DAG


def compute_signatures(dag: DAG, nonces: dict[str, str] | None = None
                       ) -> dict[str, str]:
    """Return ``{node name: hex signature}`` for every node in ``dag``.

    ``nonces`` optionally pins the nonce used for nondeterministic nodes
    (used by tests); by default a fresh uuid4 is drawn per compilation.
    """
    sigs: dict[str, str] = {}
    for name in dag.topological():
        node = dag.nodes[name]
        h = hashlib.sha256()
        h.update(node.name.encode())
        h.update(node.kind.value.encode())
        h.update(str(node.version).encode())
        if not node.deterministic:
            nonce = (nonces or {}).get(name, uuid.uuid4().hex)
            h.update(nonce.encode())
        for p in node.parents:
            h.update(sigs[p].encode())
        sigs[name] = h.hexdigest()
    return sigs


def compute_chunk_signatures(dag: DAG, sigs: dict[str, str]) -> dict:
    """Chunk-level refinement of :func:`compute_signatures`.

    Where the full signature answers "is this node's whole output
    equivalent to a prior run?", the chunk signature answers it *per data
    chunk*:

        chunk_sig(n, j) = H(name, kind, version,
                            [chunk_sig(p, j) for chunked parents],
                            [sig(p) for broadcast parents])

    seeded at chunked sources by H(name, kind, chunk_id_j) — the source
    ``version`` (which changes on every append) is deliberately left out,
    so the pre-append prefix keeps its chunk signatures and only the
    appended chunks are new work. Returns ``{node name:
    :class:`~repro.core.chunks.ChunkPlan`}`` for every node chunk
    signatures can flow to; all derivation rules live in
    :func:`repro.core.chunks.compute_chunk_plans`.
    """
    from .chunks import compute_chunk_plans
    return compute_chunk_plans(dag, sigs)


def source_version(obj) -> str:
    """Hash an arbitrary config/source blob into a version string.

    The DSL uses this to derive ``Node.version`` from operator configuration,
    so editing a hyperparameter automatically deprecates the node (and, via
    the recursive signature, all descendants) — exactly the paper's
    representational-equivalence check.
    """
    h = hashlib.sha256()
    h.update(repr(obj).encode())
    return h.hexdigest()[:16]
