# The paper's primary contribution — the Helix iterative-execution optimizer
# as a composable library: Workflow DAG + change tracking (signatures) +
# OPT-EXEC-PLAN (max-flow) + OPT-MAT-PLAN (streaming heuristic) + the
# execution engine with a content-addressed, reshard-on-load store.
from .config import (EngineConfig, ResilienceConfig, StoreConfig,
                     reset_legacy_warnings)
from .dag import DAG, Kind, Node, State, validate_states
from .signature import compute_signatures, source_version
from .oep import plan, plan_runtime, brute_force_plan
from .omp import Materializer, Policy, cumulative_runtime
from .eviction import EvictionStats, Evictor
from .remote import (FsObjectStore, ObjectStore, RemoteStats, RemoteStore,
                     TransientBackendError, as_remote_store)
from .faults import ChaosObjectStore, FaultPlan, InjectedCrash
from .store import ComputeLease, ReadPin, Store, tree_nbytes
from .locking import FileLock, SharedEwma, StorageLedger
from .costs import CostModel
from .executor import ExecutionReport, JobCancelled, execute
from .workflow import Ref, Workflow
from .session import IterationReport, IterativeSession
from .pruning import slice_from_outputs, zero_weight_extractors
from .sweep import (SweepReport, SweepVariant, VariantResult, grid,
                    random_search, run_sweep)
from .search import (ArmResult, HalvingConfig, SearchConfig, SearchDriver,
                     SearchReport, tune)

__all__ = [
    "EngineConfig", "ResilienceConfig", "StoreConfig",
    "reset_legacy_warnings",
    "DAG", "Kind", "Node", "State", "validate_states",
    "compute_signatures", "source_version",
    "plan", "plan_runtime", "brute_force_plan",
    "Materializer", "Policy", "cumulative_runtime",
    "EvictionStats", "Evictor",
    "FsObjectStore", "ObjectStore", "RemoteStats", "RemoteStore",
    "TransientBackendError", "as_remote_store",
    "ChaosObjectStore", "FaultPlan", "InjectedCrash",
    "ComputeLease", "ReadPin", "Store", "tree_nbytes", "CostModel",
    "FileLock", "SharedEwma", "StorageLedger",
    "ExecutionReport", "JobCancelled", "execute",
    "Ref", "Workflow",
    "slice_from_outputs", "zero_weight_extractors",
    "IterationReport", "IterativeSession",
    "SweepReport", "SweepVariant", "VariantResult",
    "grid", "random_search", "run_sweep",
    "ArmResult", "HalvingConfig", "SearchConfig", "SearchDriver",
    "SearchReport", "tune",
]
