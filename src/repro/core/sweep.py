"""Shared-store concurrent sweep engine: many sessions, one cache.

Helix (the paper) optimizes *one* developer's iteration loop. This driver
turns the same machinery into fleet-scale reuse, following "Exploiting
Reuse in Pipeline-Aware Hyperparameter Tuning" (Li et al., 2019) and
"Accelerating Human-in-the-loop Machine Learning" (Xin et al., 2018): run
N workflow *variants* (a knob grid or random search) concurrently against
one shared materialization store. Variants that share a DAG prefix share
its signatures, so:

* the first variant to need a shared signature computes it under the
  store's **compute lease** and force-persists it for the registered
  waiters — each shared signature is computed exactly once fleet-wide;
* every other variant either waits-and-loads (in-flight dedupe) or, if it
  plans after the value landed, gets a plain OEP LOAD from the max-flow
  planner;
* the storage budget is enforced through the store's **shared ledger**,
  and the §6.6 stale-purge is disabled (sibling variants' same-name
  entries are not stale — and deletes respect live leases regardless).

Nondeterministic operators normally draw a fresh signature nonce per
compilation and can never be shared. ``share_nondet=True`` (default) pins
one nonce map for the whole sweep — morally "fix the seed for this sweep":
identical unseeded operators across variants become equivalent and are
computed once. Disable it for strictly independent per-variant randomness.

Concurrency is thread-based (JAX is fork-hostile); the store machinery
underneath is ``flock``-based, so independent OS processes pointed at the
same workdir compose the same way — this driver is just the convenient
in-process harness.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time
import uuid
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Mapping, Sequence

from .locking import StorageLedger
from .omp import Policy
from .session import IterationReport, IterativeSession
from .signature import compute_signatures
from .store import Store
from .workflow import Workflow


@dataclasses.dataclass(frozen=True)
class SweepVariant:
    """One arm of the sweep: a label plus a zero-arg Workflow factory."""

    name: str
    build: Callable[[], Workflow]
    knobs: Any = None  # the knob value(s) this arm represents, for reports


def grid(base: Any, axes: Mapping[str, Sequence[Any]],
         build: Callable[[Any], Workflow],
         name: str = "variant") -> list[SweepVariant]:
    """Cartesian-product knob grid over a frozen knob dataclass.

    ``axes`` maps field names to candidate values; each combination yields
    a :class:`SweepVariant` whose factory builds the workflow from
    ``dataclasses.replace(base, **combo)``.
    """
    keys = list(axes)
    out = []
    for combo in itertools.product(*(axes[k] for k in keys)):
        knobs = dataclasses.replace(base, **dict(zip(keys, combo)))
        label = name + "".join(f"_{k}={v}" for k, v in zip(keys, combo))
        out.append(SweepVariant(name=label,
                                build=(lambda kn=knobs: build(kn)),
                                knobs=knobs))
    return out


def random_search(base: Any, mutate: Callable[[Any, Any], Any], n: int,
                  rng: Any, build: Callable[[Any], Workflow],
                  name: str = "rand") -> list[SweepVariant]:
    """N variants drawn by repeatedly applying ``mutate(knobs, rng)``."""
    out, cur = [], base
    for i in range(n):
        out.append(SweepVariant(name=f"{name}{i}",
                                build=(lambda kn=cur: build(kn)),
                                knobs=cur))
        cur = mutate(cur, rng)
    return out


class _SharedNonces:
    """Sweep-wide nonce map for nondeterministic nodes: first access per
    node name draws the nonce, every variant then reuses it (signatures
    still differ across variants whose node *versions* differ)."""

    def __init__(self) -> None:
        self._nonces: dict[str, str] = {}
        self._lock = threading.Lock()

    def get(self, name: str, default: str | None = None) -> str:
        with self._lock:
            if name not in self._nonces:
                self._nonces[name] = uuid.uuid4().hex
            return self._nonces[name]


@dataclasses.dataclass
class VariantResult:
    variant: SweepVariant
    report: IterationReport | None
    seconds: float
    error: BaseException | None = None

    @property
    def outputs(self) -> dict[str, Any]:
        return {} if self.report is None else self.report.outputs


@dataclasses.dataclass
class SweepReport:
    results: list[VariantResult]
    wall_seconds: float
    store_bytes: int

    @property
    def outputs(self) -> dict[str, dict[str, Any]]:
        return {r.variant.name: r.outputs for r in self.results}

    def fleet_computes(self) -> dict[str, int]:
        """How many variants actually *computed* each signature (planned
        COMPUTE and not turned into a load by the in-flight dedupe).
        With dedupe on, shared signatures must all be 1."""
        from .dag import State
        counts: dict[str, int] = {}
        for r in self.results:
            if r.report is None:
                continue
            ex = r.report.execution
            for n, s in ex.states.items():
                if s is State.COMPUTE and n not in ex.deduped:
                    sig = r.report.sigs[n]
                    counts[sig] = counts.get(sig, 0) + 1
        return counts

    def raise_errors(self) -> None:
        for r in self.results:
            if r.error is not None:
                raise r.error


def run_sweep(workdir: str,
              variants: Sequence[SweepVariant],
              *,
              n_concurrent: int | None = None,
              policy: Policy = Policy.OPT,
              storage_budget_bytes: float = float("inf"),
              max_workers: int = 1,
              prefetch_depth: int = 4,
              async_materialization: bool = False,
              share_nondet: bool = True,
              dedupe_inflight: bool = True,
              dedupe_wait_seconds: float = 3600.0,
              horizon: float | None = None) -> SweepReport:
    """Run every variant against one shared store in ``workdir``.

    Each variant gets its own :class:`IterativeSession` over the *same*
    workdir (shared store, shared cost statistics, shared budget ledger),
    with in-flight dedupe on and stale-purging off. ``n_concurrent`` bounds
    how many variants run at once (default: all); ``max_workers`` /
    ``prefetch_depth`` / ``async_materialization`` are forwarded to each
    session's pipelined executor.

    ``horizon`` defaults to the number of variants: a materialized shared
    value is expected to be reused by roughly every sibling, which is
    exactly the amortization OMP's threshold wants (see omp.py).
    ``dedupe_wait_seconds`` (default 1 h) must exceed the longest shared
    node's compute time, or waiters time out and duplicate it — it is
    only the escape hatch that keeps a crashed-but-lease-holding-via-NFS
    style pathology from stalling the sweep forever.
    """
    variants = list(variants)
    if not variants:
        return SweepReport(results=[], wall_seconds=0.0, store_bytes=0)
    n_concurrent = len(variants) if n_concurrent is None \
        else max(1, int(n_concurrent))
    nonces = _SharedNonces() if share_nondet else None
    hz = float(len(variants)) if horizon is None else horizon

    # Pre-pass: compile every variant's DAG once (cheap — node declaration
    # only) to learn which signatures recur across variants. Those are the
    # shared prefixes; the executor force-persists them on lease-compute so
    # each is computed exactly once fleet-wide even without a waiter racing
    # the holder. Signatures are stable across the re-compilation inside
    # each session because the nonce map is pinned.
    sig_count: dict[str, int] = {}
    for v in variants:
        for sig in set(compute_signatures(v.build().build(),
                                          nonces=nonces).values()):
            sig_count[sig] = sig_count.get(sig, 0) + 1
    share_sigs = frozenset(s for s, c in sig_count.items() if c >= 2)

    # Open (and heal) the store once before the fleet does, and reconcile
    # the shared budget ledger with what is actually on disk — sessions
    # without a ledger (or crashes between reserve and save) let the
    # on-disk used-bytes drift upward, which would otherwise starve every
    # future sweep's materializations. No sibling of THIS sweep has
    # started yet; a held lease means some OTHER process's fleet is
    # mid-run on this workdir, and its live reservations must not be
    # erased — skip the reconcile then (drift heals on the next quiet
    # open instead).
    store = Store(os.path.join(workdir, "store"))
    if not store.any_live_lease():
        StorageLedger(store.ledger_path).reset(float(store.total_bytes()))

    def run_one(variant: SweepVariant) -> VariantResult:
        t0 = time.perf_counter()
        try:
            sess = IterativeSession(
                workdir, policy=policy,
                storage_budget_bytes=storage_budget_bytes,
                async_materialization=async_materialization,
                horizon=hz, max_workers=max_workers,
                prefetch_depth=prefetch_depth,
                dedupe_inflight=dedupe_inflight,
                dedupe_wait_seconds=dedupe_wait_seconds,
                shared_budget=True, purge_stale=False,
                nondet_reusable=share_nondet)
            report = sess.run(variant.build(), nonces=nonces,
                              share_sigs=share_sigs)
            return VariantResult(variant=variant, report=report,
                                 seconds=time.perf_counter() - t0)
        except BaseException as e:
            return VariantResult(variant=variant, report=None,
                                 seconds=time.perf_counter() - t0, error=e)

    t_start = time.perf_counter()
    if n_concurrent == 1:
        results = [run_one(v) for v in variants]
    else:
        with ThreadPoolExecutor(
                max_workers=n_concurrent,
                thread_name_prefix="helix-sweep") as pool:
            results = list(pool.map(run_one, variants))
    wall = time.perf_counter() - t_start

    store_bytes = 0
    for r in results:
        if r.report is not None:
            store_bytes = max(store_bytes, r.report.store_bytes)
    return SweepReport(results=results, wall_seconds=wall,
                       store_bytes=store_bytes)
