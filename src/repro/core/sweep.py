"""Fixed-batch hyperparameter sweeps as session-server submissions.

Helix (the paper) optimizes *one* developer's iteration loop. This driver
turns the same machinery into fleet-scale reuse, following "Exploiting
Reuse in Pipeline-Aware Hyperparameter Tuning" (Li et al., 2019) and
"Accelerating Human-in-the-loop Machine Learning" (Xin et al., 2018): run
K workflow *variants* (a knob grid or random search) concurrently against
one shared materialization store.

Since ISSUE 7 this module is the *thin, fixed-schedule baseline driver*:
the user picks the K arms up front, they are submitted as one held batch,
and every arm runs to completion. Its deliberately static shape is what
makes it the reproducible baseline for multi-host and fifo-vs-prefix
comparisons. The *adaptive* driver — dynamic arm choice under a budget,
reuse-aware frontier ordering via the server's ``estimate`` RPC,
successive-halving early stopping, mutation search — is
:class:`repro.core.search.SearchDriver`, which talks to the same server
through the client protocol instead of holding a batch.

Since PR 3 a sweep is literally K submissions to an in-process
:class:`~repro.serve.server.SessionServer` (submitted as one held batch so
the global scheduler sees all multiplicities up front). The server brings:

* **shared-prefix-first scheduling** — variants that would newly compute a
  widely shared prefix dispatch first; siblings of an in-flight shared
  computation yield their slot to independent arms (they would mostly
  block on its compute lease), lease-following the leader only when
  nothing independent remains. ``schedule="fifo"`` restores PR 2's
  lease-contention-only ordering.
* **observed amortization** — the live signature-multiplicity map feeds
  OMP (see omp.py ``multiplicity``), superseding PR 2's static horizon≈K
  guess. ``horizon`` remains available as an explicit floor.
* **one elastic worker pool** — all K sessions draw executor workers from
  one process-wide pool instead of pooling independently.

The PR 2 correctness properties are unchanged (they live in the store's
lease protocol, not the scheduler): each shared signature is computed at
most once fleet-wide, the storage budget is enforced through the shared
ledger, the §6.6 stale-purge is disabled (sibling variants' same-name
entries are not stale), and with ``share_nondet=True`` (default) one
pinned nonce map makes identical unseeded operators sweep-equivalent —
morally "fix the seed for this sweep".

Concurrency is thread-based (JAX is fork-hostile); the store machinery
underneath is ``flock``-based, so independent OS processes pointed at the
same workdir compose the same way — this driver is just the convenient
in-process harness.

**Multi-host mode** (``n_hosts > 1``): the K submissions are spread
round-robin over M session servers, each owning its *own* workdir-local
store — the deployment shape of one server per host. With ``remote`` set
(a shared object-store tier, see remote.py) the hosts share
materializations through it: cross-host in-flight dedupe via TTL lease
objects, write-through uploads, read-through fetches. Without ``remote``
the hosts fall back to sharing one workdir (the PR 2 N-process path —
only meaningful when ``workdir`` is a shared filesystem). In-process
"hosts" are a faithful harness for the real thing because nothing they
share goes through process memory except the ObjectStore handle, which
is itself just files.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
import os
import time
from typing import Any, Callable, Mapping, Sequence

from .config import (UNSET, EngineConfig, ResilienceConfig, StoreConfig,
                     resolve)
from .session import IterationReport
from .workflow import Workflow


@dataclasses.dataclass(frozen=True)
class SweepVariant:
    """One arm of the sweep: a label plus a zero-arg Workflow factory."""

    name: str
    build: Callable[[], Workflow]
    knobs: Any = None  # the knob value(s) this arm represents, for reports
    seed: int | None = None  # the RNG seed that drew this arm, for replay


def grid(base: Any, axes: Mapping[str, Sequence[Any]],
         build: Callable[[Any], Workflow],
         name: str = "variant") -> list[SweepVariant]:
    """Cartesian-product knob grid over a frozen knob dataclass.

    ``axes`` maps field names to candidate values; each combination yields
    a :class:`SweepVariant` whose factory builds the workflow from
    ``dataclasses.replace(base, **combo)``.
    """
    keys = list(axes)
    out = []
    for combo in itertools.product(*(axes[k] for k in keys)):
        knobs = dataclasses.replace(base, **dict(zip(keys, combo)))
        label = name + "".join(f"_{k}={v}" for k, v in zip(keys, combo))
        out.append(SweepVariant(name=label,
                                build=(lambda kn=knobs: build(kn)),
                                knobs=knobs))
    return out


def random_search(base: Any, mutate: Callable[[Any, Any], Any], n: int,
                  rng: Any = None, build: Callable[[Any], Workflow] = None,
                  name: str = "rand", *,
                  seed: int | None = None) -> list[SweepVariant]:
    """N variants drawn by repeatedly applying ``mutate(knobs, rng)``.

    Prefer ``seed`` over passing a pre-built ``rng``: the draw sequence
    is then a pure function of ``(base, mutate, n, seed)`` and the seed
    is recorded on every variant (``SweepVariant.seed``, visible in
    sweep reports via ``result.variant.seed``), so a tuning run can be
    replayed bit-identically. An explicit ``rng`` still wins when given
    (its state is the caller's business); ``seed`` is then recorded for
    provenance only.
    """
    if build is None:
        raise TypeError("random_search requires build=")
    if rng is None:
        import numpy as np
        rng = np.random.default_rng(seed)
    out, cur = [], base
    for i in range(n):
        out.append(SweepVariant(name=f"{name}{i}",
                                build=(lambda kn=cur: build(kn)),
                                knobs=cur, seed=seed))
        cur = mutate(cur, rng)
    return out


@dataclasses.dataclass
class VariantResult:
    """One arm's outcome: its report (or the error that stopped it) and
    its run seconds (queue wait excluded)."""

    variant: SweepVariant
    report: IterationReport | None
    seconds: float
    error: BaseException | None = None

    @property
    def outputs(self) -> dict[str, Any]:
        """The arm's workflow outputs ({} when it errored)."""
        return {} if self.report is None else self.report.outputs


@dataclasses.dataclass
class SweepReport:
    """Fleet-level outcome of :func:`run_sweep` over all variants."""

    results: list[VariantResult]
    wall_seconds: float
    store_bytes: int
    # Fleet evictor stats over the whole sweep (empty when eviction off;
    # summed across hosts in multi-host mode).
    evictions: dict = dataclasses.field(default_factory=dict)
    # Remote-tier stats (uploads/fetches/evictions/vetoes — see
    # remote.RemoteStats), summed across hosts; empty without a tier.
    remote: dict = dataclasses.field(default_factory=dict)

    @property
    def outputs(self) -> dict[str, dict[str, Any]]:
        """Outputs keyed by variant name."""
        return {r.variant.name: r.outputs for r in self.results}

    def fleet_computes(self) -> dict[str, int]:
        """How many variants actually *computed* each signature (planned
        COMPUTE and not turned into a load by the in-flight dedupe).

        A count > 1 is either a deliberate planner choice (the value was
        loadable but recomputing was cheaper — see
        :meth:`wasted_recomputes`) or a coordination failure."""
        from .dag import State
        counts: dict[str, int] = {}
        for r in self.results:
            if r.report is None:
                continue
            ex = r.report.execution
            for n, s in ex.states.items():
                if s is State.COMPUTE and n not in ex.deduped:
                    sig = r.report.sigs[n]
                    counts[sig] = counts.get(sig, 0) + 1
        return counts

    def wasted_recomputes(self) -> int:
        """Shared signatures computed more than once where reuse was
        actually on the table — true coordination failures.

        A duplicate compute is *not* wasted when the later variant's
        max-flow planner saw the loadable entry and still chose COMPUTE
        because loading was costlier (``ExecutionReport.chose_compute``) —
        that is reuse economics working, e.g. a sub-millisecond extractor
        is cheaper to rerun than to read back. The acceptance bar for the
        fleet engines is that this method returns 0: no variant ever
        recomputes a shared value because coordination lost it."""
        from .dag import State
        # Per signature: computes that were NOT a deliberate
        # cheaper-to-recompute choice. One such compute per signature is
        # the unavoidable cold start; a second one means two sessions
        # each believed nobody had the value — a coordination failure.
        blind: dict[str, int] = {}
        for r in self.results:
            if r.report is None:
                continue
            ex = r.report.execution
            for n, s in ex.states.items():
                if (s is State.COMPUTE and n not in ex.deduped
                        and n not in ex.chose_compute):
                    sig = r.report.sigs[n]
                    blind[sig] = blind.get(sig, 0) + 1
        return sum(1 for c in blind.values() if c > 1)

    def raise_errors(self) -> None:
        """Re-raise the first variant error, if any arm failed."""
        for r in self.results:
            if r.error is not None:
                raise r.error


def run_sweep(workdir: str,
              variants: Sequence[SweepVariant],
              *,
              n_concurrent: Any = UNSET,
              policy: Any = UNSET,
              storage_budget_bytes: Any = UNSET,
              max_workers: Any = UNSET,
              prefetch_depth: Any = UNSET,
              async_materialization: Any = UNSET,
              share_nondet: Any = UNSET,
              dedupe_inflight: Any = UNSET,
              dedupe_wait_seconds: Any = UNSET,
              horizon: Any = UNSET,
              schedule: Any = UNSET,
              pool_workers: Any = UNSET,
              evict_to_admit: Any = UNSET,
              n_hosts: int = 1,
              remote: Any = UNSET,
              engine: EngineConfig | None = None,
              storage: StoreConfig | None = None,
              resilience: ResilienceConfig | None = None) -> SweepReport:
    """Run every variant against one shared store in ``workdir``.

    Configuration comes as the layered dataclasses ``engine=`` /
    ``storage=`` / ``resilience=`` (see :mod:`repro.core.config`);
    ``n_concurrent`` maps to ``EngineConfig.n_sessions`` (default: all
    variants at once). The loose keyword arguments are a deprecated
    shim kept for existing call sites — each warns once per process and
    overrides the corresponding config field. ``n_hosts`` stays a real
    parameter: it is sweep *topology*, not engine configuration.

    Spins up an in-process :class:`~repro.serve.server.SessionServer`
    over ``workdir``, submits the K variants as one held batch (so the
    global scheduler sees every shared signature's multiplicity before
    ordering), waits for all of them, and shuts the server down. Each
    variant runs in its own session over the same store / cost statistics
    / budget ledger, with in-flight dedupe on and stale-purging off.

    ``n_concurrent`` bounds how many variants run at once (default: all);
    ``max_workers`` / ``prefetch_depth`` / ``async_materialization`` are
    forwarded to each session's pipelined executor, whose workers come
    from one shared pool of ``pool_workers`` (default: enough for every
    concurrent session).

    ``schedule`` picks the dispatch policy: ``"prefix"`` (default) is the
    server's shared-prefix-first order with sibling deferral;  ``"fifo"``
    reproduces PR 2's arrival-order dispatch where siblings coordinate
    through lease contention alone.

    ``horizon`` is now only an explicit static floor for OMP's
    amortization: by default the server's live signature-multiplicity map
    tells OMP *exactly* how many siblings want each value (superseding
    the old horizon≈K guess). ``dedupe_wait_seconds`` (default 1 h) must
    exceed the longest shared node's compute time, or waiters time out
    and duplicate it — it is only the escape hatch that keeps a
    crashed-but-lease-holding-via-NFS style pathology from stalling the
    sweep forever.

    ``evict_to_admit`` (default True) gives the fleet benefit-weighted
    eviction under the shared budget: a materialization that does not
    fit evicts the lowest-benefit unleased entries (never ones a live
    variant still wants — the server's multiplicity map vetoes those)
    instead of being refused. ``SweepReport.evictions`` carries the
    fleet evictor's stats.

    ``n_hosts`` > 1 turns on multi-host mode (module docstring): the
    submissions spread round-robin over that many session servers, each
    with its own local store under ``workdir/host<i>`` — sharing work
    through the ``remote`` tier when one is given (a
    :class:`~repro.core.remote.RemoteStore`, an ObjectStore backend, or
    a filesystem path), else through one common ``workdir``. Session
    slots split evenly across hosts. ``remote`` also works with a
    single host (write-through warm-up of a fleet tier).
    """
    from ..serve.server import (SessionServer,     # local: avoids
                                SharedNonces)      # an import cycle

    variants = list(variants)
    if not variants:
        return SweepReport(results=[], wall_seconds=0.0, store_bytes=0)
    eng = resolve(
        "run_sweep", EngineConfig, engine,
        site_defaults=dict(share_nondet=True, dedupe_inflight=True),
        legacy=dict(
            n_concurrent=("n_sessions", n_concurrent),
            policy=("policy", policy),
            max_workers=("max_workers", max_workers),
            prefetch_depth=("prefetch_depth", prefetch_depth),
            async_materialization=("async_materialization",
                                   async_materialization),
            share_nondet=("share_nondet", share_nondet),
            dedupe_inflight=("dedupe_inflight", dedupe_inflight),
            horizon=("horizon", horizon),
            schedule=("schedule", schedule),
            pool_workers=("pool_workers", pool_workers)))
    sto = resolve(
        "run_sweep", StoreConfig, storage,
        site_defaults=dict(shared_budget=True, purge_stale=False),
        legacy=dict(
            storage_budget_bytes=("budget_bytes", storage_budget_bytes),
            evict_to_admit=("evict_to_admit", evict_to_admit),
            remote=("remote", remote)))
    res = resolve(
        "run_sweep", ResilienceConfig, resilience,
        site_defaults=dict(dedupe_wait_seconds=3600.0),
        legacy=dict(
            dedupe_wait_seconds=("dedupe_wait_seconds",
                                 dedupe_wait_seconds)))
    n_concurrent = len(variants) if eng.n_sessions is None \
        else max(1, int(eng.n_sessions))
    if eng.schedule == "fifo" and eng.horizon is None:
        # The fifo baseline must be PR 2 end-to-end: no observed
        # multiplicity (the server already withholds it in fifo mode),
        # and PR 2's static horizon≈K amortization default.
        eng = dataclasses.replace(eng, horizon=float(len(variants)))
    n_hosts = max(1, min(int(n_hosts), len(variants)))
    slots_per_host = max(1, math.ceil(n_concurrent / n_hosts))
    # One nonce map for the whole fleet: nondeterministic operators stay
    # sweep-equivalent across hosts, exactly as within one server.
    fleet_nonces = SharedNonces() if eng.share_nondet and n_hosts > 1 \
        else None

    servers = [
        SessionServer(
            # Per-host workdirs only when a remote tier connects them;
            # without one, "hosts" share the workdir itself (the PR 2
            # N-process path) — private workdirs with no tier would
            # silently lose all cross-host reuse.
            workdir if n_hosts == 1 or sto.remote is None
            else os.path.join(workdir, f"host{h}"),
            engine=dataclasses.replace(eng, n_sessions=slots_per_host),
            storage=sto, resilience=res,
            nonces=fleet_nonces)
        for h in range(n_hosts)]
    t_start = time.perf_counter()
    jobs: list = []
    try:
        # One held batch per server: every variant's signatures enter
        # each host's multiplicity map before its first dispatch
        # decision. (Multiplicity maps are per-host; cross-host sharing
        # flows through the remote tier's leases, not the scheduler.)
        with contextlib.ExitStack() as stack:
            for server in servers:
                stack.enter_context(server.hold_dispatch())
            for i, v in enumerate(variants):
                try:
                    jobs.append(servers[i % n_hosts].submit(v.build,
                                                            name=v.name))
                except BaseException as e:  # a broken factory is one arm's
                    jobs.append(e)          # failure, not the sweep's
            if n_hosts > 1:
                # Cross-host share set: a signature two *hosts* need
                # must be force-persisted (and uploaded before the
                # lease releases) by whichever host computes it — each
                # server's own multiplicity map only sees its local
                # arms, so without this a one-arm-per-host fleet would
                # persist nothing and every host would recompute the
                # common prefix.
                per_host: list[set] = [set() for _ in servers]
                for i, j in enumerate(jobs):
                    if not isinstance(j, BaseException):
                        per_host[i % n_hosts] |= set(j.sigs)
                counts: dict[str, int] = {}
                for sigs in per_host:
                    for sig in sigs:
                        counts[sig] = counts.get(sig, 0) + 1
                fleet_shared = {s for s, c in counts.items() if c >= 2}
                for server in servers:
                    server.share_across(fleet_shared)
        for h, server in enumerate(servers):
            server.wait_all([j for i, j in enumerate(jobs)
                             if i % n_hosts == h
                             and not isinstance(j, BaseException)])
    finally:
        for server in servers:
            server.shutdown()
    wall = time.perf_counter() - t_start
    evictions: dict = {}
    remote_stats: dict = {}
    seen_remotes: set[int] = set()
    for server in servers:
        if server.evictor is not None:
            for k, n in server.evictor.stats.snapshot().items():
                evictions[k] = evictions.get(k, 0) + n
        tier = server.store.remote
        if tier is not None and id(tier) not in seen_remotes:
            seen_remotes.add(id(tier))   # a shared injected instance
            for k, n in tier.stats.snapshot().items():  # counts once
                remote_stats[k] = remote_stats.get(k, 0) + n

    results = [
        VariantResult(variant=v, report=None, seconds=0.0, error=j)
        if isinstance(j, BaseException) else
        VariantResult(variant=v, report=j.report,
                      seconds=j.run_seconds, error=j.error)
        for v, j in zip(variants, jobs)]
    store_bytes = 0
    for r in results:
        if r.report is not None:
            store_bytes = max(store_bytes, r.report.store_bytes)
    return SweepReport(results=results, wall_seconds=wall,
                       store_bytes=store_bytes, evictions=evictions,
                       remote=remote_stats)
