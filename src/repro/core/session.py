"""IterativeSession — the workflow lifecycle driver (paper §2.2, Fig. 2).

    W_t ──compile──▶ DAG ──slice──▶ sliced DAG
        ──signatures/diff──▶ original set + equivalent materializations
        ──OEP (max-flow)──▶ states {compute, load, prune}
        ──execute + OMP──▶ results, selective materialization
        ──record stats──▶ cost model (persisted)

Because signatures, cost statistics, and the store all persist on disk, a
*process restart* is indistinguishable from the next iteration of the same
workflow: completed work is equivalent → loaded; in-flight work is original →
recomputed. That is the fault-tolerance story at pod scale, and Theorem 1
gives its correctness argument.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Callable, Mapping

from .config import (UNSET, EngineConfig, ResilienceConfig, StoreConfig,
                     resolve)
from .costs import CostModel
from .dag import State
from .eviction import Evictor
from .executor import ExecutionReport, execute
from .locking import StorageLedger
from .chunks import protected_chunk_sigs
from .omp import Materializer, Policy, delta_fraction
from .oep import plan
from .pruning import slice_from_outputs, stale_variants
from .remote import ObjectStore, RemoteStore, as_remote_store
from .signature import compute_chunk_signatures, compute_signatures
from .store import Store
from .workflow import Workflow


@dataclasses.dataclass
class IterationReport:
    """Everything one :meth:`IterativeSession.run` produced: the execution
    report, the signature map, the original/sliced sets, and store
    accounting."""

    execution: ExecutionReport
    sigs: dict[str, str]
    original: set[str]
    sliced_away: set[str]
    store_bytes: int
    purged_bytes: int
    # Evictor-stat deltas over this run (empty when no evictor is wired,
    # fleet-wide deltas when the evictor is shared by a session server).
    evictions: dict = dataclasses.field(default_factory=dict)

    @property
    def outputs(self) -> dict[str, Any]:
        """Values of the workflow's mandatory output nodes."""
        return self.execution.outputs

    @property
    def total_seconds(self) -> float:
        """Wall clock of the execution phase."""
        return self.execution.total_seconds

    @property
    def deduped(self) -> dict[str, str]:
        """COMPUTE-planned nodes another session's compute turned into
        loads (in-flight dedupe)."""
        return self.execution.deduped


class IterativeSession:
    """Drives iterations of one workflow.

    Configuration comes in three layered frozen dataclasses (see
    ``repro.core.config``): ``engine=`` (:class:`EngineConfig` — policy,
    executor width, prefetch, async materialization, horizon, dedupe),
    ``storage=`` (:class:`StoreConfig` — budget, eviction, remote tier,
    ledger sharing, stale purging) and ``resilience=``
    (:class:`ResilienceConfig` — dedupe lease waits, remote
    retry/backoff, fault injection). The loose keyword arguments below
    are the pre-config API; they still work, override the dataclasses,
    and emit one :class:`DeprecationWarning` per kwarg name. The fully
    resolved groups are exposed as ``self.engine_config`` /
    ``self.store_config`` / ``self.resilience_config``.

    Execution-engine knobs (see ``executor.py`` for the scheduler model):

    ``max_workers``
        Width of the executor's worker pool. 1 (default) is the paper's
        strictly sequential engine; >1 runs independent DAG branches
        concurrently and overlaps LOAD I/O with compute. Outputs and
        materialization decisions are identical for any value on
        deterministic workflows.
    ``prefetch_depth``
        Maximum number of LOAD values resident in host memory before a
        consumer has used them (bounds prefetch memory; ≥1 enables
        prefetching when ``max_workers > 1``).
    ``async_materialization``
        Route materialization writes through the store's dedicated writer
        queue instead of blocking the executing worker; write wall time is
        still accounted in ``ExecutionReport.mat_seconds``.

    Fleet knobs (many sessions, one workdir — see sweep.py and serve/):

    ``dedupe_inflight``
        Compute-once protocol: COMPUTE nodes take the store's fleet-wide
        per-signature lease; sessions needing a signature someone else is
        computing wait and load the published result instead.
    ``dedupe_wait_seconds``
        Upper bound on waiting for another session's lease before
        falling back to computing locally (the deadlock escape hatch).
        Must exceed the longest shared node's compute time or waiters
        duplicate it; sweeps default this to an hour.
    ``shared_budget``
        Enforce ``storage_budget_bytes`` against the store's shared
        on-disk ledger, so N concurrent sessions split one budget.
    ``evict_to_admit``
        When the budget is finite, attach a benefit-weighted
        :class:`~repro.core.eviction.Evictor`: a materialization that
        does not fit evicts the lowest-benefit-density unleased store
        entries (C(n)/l_i × observed reuse; see eviction.py) instead of
        being refused. Planned LOADs are pinned by read leases and never
        evicted. Default True; False restores refuse-on-exhausted.
    ``evictor`` / ``live_sigs``
        Injected by the session server: one shared evictor (fleet-wide
        stats) and the live-multiplicity veto (``sig -> bool`` — entries
        live clients still want are never eviction candidates).
    ``purge_stale``
        The paper's §6.6 purge of prior materializations of *original*
        operators. Must be disabled for concurrent sweeps: sibling
        variants legitimately hold same-name/different-signature entries
        that are not stale. (Deletes always respect other sessions' live
        leases regardless.)

    Server knobs (one long-running process hosting many sessions — see
    ``repro.serve``):

    ``remote``
        Attach a fleet-shared remote materialization tier (see
        remote.py): a :class:`~repro.core.remote.RemoteStore`, a raw
        :class:`~repro.core.remote.ObjectStore` backend, or a
        filesystem path (the shared-mount reference deployment). The
        local store then write-through/read-through caches it —
        materializations upload asynchronously, local misses fetch, and
        compute leases extend across hosts via TTL lease objects.
        Ignored when ``store`` is injected (the store's own tier wins).
    ``store`` / ``cost_model``
        Injected shared instances. The session server opens one
        :class:`Store` (one writer queue, one heal pass, one bandwidth
        EWMA) and one :class:`CostModel` per workdir and hands them to
        every session it hosts; standalone sessions construct their own.
    ``worker_pool``
        A ``repro.serve.SharedWorkerPool``: executor workers beyond the
        session's own thread are borrowed from one process-wide pool
        instead of each session pooling independently.
    ``multiplicity``
        ``sig -> expected future loads`` fed to OMP's amortized
        materialization threshold (the server's live cross-client
        signature-multiplicity map; see omp.py).
    """

    def __init__(self, workdir: str,
                 policy: Policy = UNSET,
                 storage_budget_bytes: float = UNSET,
                 async_materialization: bool = UNSET,
                 horizon: float = UNSET,
                 max_workers: int = UNSET,
                 prefetch_depth: int = UNSET,
                 dedupe_inflight: bool = UNSET,
                 dedupe_wait_seconds: float = UNSET,
                 shared_budget: bool = UNSET,
                 purge_stale: bool = UNSET,
                 nondet_reusable: bool = UNSET,
                 remote: RemoteStore | ObjectStore | str | None = UNSET,
                 store: Store | None = None,
                 cost_model: CostModel | None = None,
                 worker_pool=None,
                 multiplicity: Callable[[str], float] | None = None,
                 evict_to_admit: bool = UNSET,
                 evictor: Evictor | None = None,
                 live_sigs: Callable[[str], bool] | None = None,
                 ledger=None,
                 *,
                 engine: EngineConfig | None = None,
                 storage: StoreConfig | None = None,
                 resilience: ResilienceConfig | None = None):
        eng = resolve(
            "IterativeSession", EngineConfig, engine,
            site_defaults=dict(share_nondet=False, dedupe_inflight=False),
            legacy=dict(
                policy=("policy", policy),
                async_materialization=("async_materialization",
                                       async_materialization),
                horizon=("horizon", horizon),
                max_workers=("max_workers", max_workers),
                prefetch_depth=("prefetch_depth", prefetch_depth),
                dedupe_inflight=("dedupe_inflight", dedupe_inflight),
                nondet_reusable=("share_nondet", nondet_reusable)))
        sto = resolve(
            "IterativeSession", StoreConfig, storage,
            site_defaults=dict(shared_budget=False, purge_stale=True),
            legacy=dict(
                storage_budget_bytes=("budget_bytes", storage_budget_bytes),
                shared_budget=("shared_budget", shared_budget),
                purge_stale=("purge_stale", purge_stale),
                evict_to_admit=("evict_to_admit", evict_to_admit),
                remote=("remote", remote)))
        res = resolve(
            "IterativeSession", ResilienceConfig, resilience,
            site_defaults=dict(dedupe_wait_seconds=600.0),
            legacy=dict(
                dedupe_wait_seconds=("dedupe_wait_seconds",
                                     dedupe_wait_seconds)))
        self.engine_config, self.store_config, self.resilience_config = \
            eng, sto, res
        os.makedirs(workdir, exist_ok=True)
        self.workdir = workdir
        self.store = store if store is not None \
            else Store(os.path.join(workdir, "store"),
                       remote=as_remote_store(
                           sto.remote,
                           max_retries=res.remote_max_retries,
                           retry_backoff=res.remote_retry_backoff,
                           faults=res.faults),
                       mem_budget_bytes=sto.mem_budget_bytes,
                       mem_writeback=sto.mem_writeback)
        self.cost_model = cost_model if cost_model is not None \
            else CostModel(os.path.join(workdir, "costs.json"))
        # ``ledger=`` injects a pre-built budget ledger — the tenant
        # server passes a ScopedLedger so this session's reservations
        # also debit its tenant's quota; default is the plain fleet
        # StorageLedger whenever the budget is shared.
        if ledger is None and sto.shared_budget:
            ledger = StorageLedger(self.store.ledger_path)
            ledger.ensure(float(self.store.total_bytes()))
        self.evictor = evictor
        if (self.evictor is None and sto.evict_to_admit
                and sto.budget_bytes != float("inf")):
            self.evictor = Evictor(self.store, cost_model=self.cost_model,
                                   live_multiplicity=live_sigs)
        self.materializer = Materializer(
            policy=eng.policy, storage_budget_bytes=sto.budget_bytes,
            horizon=1.0 if eng.horizon is None else eng.horizon,
            ledger=ledger,
            nondet_reusable=eng.share_nondet,
            multiplicity=multiplicity,
            evictor=self.evictor)
        if ledger is None:
            self.materializer.used_bytes = float(self.store.total_bytes())
        self.async_materialization = eng.async_materialization
        self.max_workers = eng.max_workers
        self.prefetch_depth = eng.prefetch_depth
        self.dedupe_inflight = eng.dedupe_inflight
        self.dedupe_wait_seconds = res.dedupe_wait_seconds
        self.purge_stale = sto.purge_stale
        self.worker_pool = worker_pool
        self.iteration = 0

    # ------------------------------------------------------------------------------
    def run(self, workflow: Workflow,
            load_shardings: Mapping[str, Callable] | None = None,
            nonces: Mapping[str, str] | None = None,
            share_sigs: frozenset | set | None = None,
            cancel: "threading.Event | None" = None) -> IterationReport:
        """Run one iteration. ``nonces`` optionally pins the signature
        nonces of nondeterministic nodes — the sweep driver passes one
        shared nonce map so identical unseeded operators across concurrent
        variants become equivalent (computed once, loaded by the rest).
        ``share_sigs`` marks signatures sibling sessions also need (the
        executor force-persists those on lease-compute). ``cancel``
        forwards a cooperative cancel flag to the executor (checked
        between nodes; the run raises
        :class:`~repro.core.executor.JobCancelled` after settling)."""
        dag = workflow.build()
        sigs = compute_signatures(dag, nonces=nonces)
        ev_before = (self.evictor.stats.snapshot()
                     if self.evictor is not None else {})

        # §5.4 program slicing.
        keep = slice_from_outputs(dag)
        sliced = dag.subgraph(keep)

        # Chunk-granular refinement (chunks.py): per-chunk signatures for
        # every node they can flow to. Incrementally maintainable nodes
        # execute per-chunk, splicing cached chunks; everything else
        # keeps the paper's whole-value semantics.
        chunk_plans = compute_chunk_signatures(sliced, sigs)

        # One store stat per node per planning pass (shared NFS-style
        # workdirs make metadata I/O expensive; the two uses below must
        # also agree on one snapshot).
        in_store = {n: self.store.has(sigs[n]) for n in sliced.topological()}

        # §4.2 change tracking: original ⇔ signature never seen before.
        # The store is consulted too: an equivalent materialization on disk
        # (Def. 3) proves some session computed this signature even if the
        # shared cost statistics have not flushed yet — without this, a
        # session dispatched the moment a sibling's shared prefix lands
        # (the server's prefix-first schedule does exactly that) would
        # force-COMPUTE a value it could load.
        original = {n for n in sliced.topological()
                    if self.cost_model.is_original(sigs[n])
                    and not in_store[n]}

        # §5.1 operator metrics.
        compute_cost: dict[str, float] = {}
        load_cost: dict[str, float | None] = {}
        for n in sliced.topological():
            node = sliced.nodes[n]
            compute_cost[n] = self.cost_model.compute_cost(
                sigs[n], hint=node.cost_hint)
            if n in chunk_plans:
                # Incremental pricing: the executor will recompute only
                # the store-missing chunks, so the expected cost this
                # iteration is the historical whole-value cost scaled by
                # the missing fraction (omp.delta_fraction). After an
                # append this is what makes OEP prefer compute-and-splice
                # over loading a stale whole-value entry.
                compute_cost[n] *= delta_fraction(chunk_plans[n],
                                                  self.store)
            if in_store[n]:
                meta = self.store.meta(sigs[n])
                # A chunked manifest's own nbytes is metadata-sized; the
                # load cost that matters is manifest + referenced chunks.
                nb = (meta["nbytes"]
                      + meta.get("chunked", {}).get("chunk_bytes", 0))
                # Per-tier l_i: a memory-resident value prices at RAM
                # bandwidth, a remote-only one at fetch bandwidth — the
                # cheapest tier that can actually serve the signature.
                load_cost[n] = self.store.est_load_seconds(nb, sig=sigs[n])
            else:
                load_cost[n] = None

        # §5.2 OEP via max-flow. Planned LOADs are pinned with read
        # leases so a concurrent session's eviction cannot yank them
        # during execution; an entry that vanished in the plan→pin window
        # (another session's purge won that race) forces a replan with
        # its load marked unavailable — the executor's LOAD path has no
        # compute fallback, so it must never start with a dead plan.
        for _ in range(len(sliced) + 1):
            states = plan(sliced, compute_cost, load_cost, original)
            read_leases = [lease for n, s in states.items()
                           if s is State.LOAD
                           for lease in [self.store.acquire_read(sigs[n])]
                           if lease is not None]
            vanished = [n for n, s in states.items()
                        if s is State.LOAD and not self.store.has(sigs[n])]
            if not vanished:
                break
            for lease in read_leases:
                lease.release()
            for n in vanished:
                load_cost[n] = None
        try:
            # Purge stale materializations of original operators (§6.6:
            # "Helix purges any previous materialization of original
            # operators prior to execution"). Skipped in sweep mode, where
            # sibling variants' same-name entries are not stale.
            purged = 0
            if self.purge_stale:
                # keep_chunks: a stale chunked manifest (pre-append
                # variant of a node this iteration re-derives) shares its
                # prefix chunks with the manifest about to be spliced —
                # the manifest goes, the still-valid chunks stay.
                protected = protected_chunk_sigs(chunk_plans)
                by_name = self.store.sigs_by_name()
                for old_sig in stale_variants(by_name, original, sigs):
                    purged += self.store.delete(old_sig,
                                                keep_chunks=protected)
                # Foreign credit: the purged entries may have been paid
                # for by a previous session — this instance never
                # reserved those bytes, so the credit must not shrink
                # its reserved-by-me mirror (ledger-only in fleet mode).
                self.materializer.credit_foreign(purged)

            report = execute(
                sliced, sigs, states, self.store, self.materializer,
                load_shardings=load_shardings,
                async_materialization=self.async_materialization,
                max_workers=self.max_workers,
                prefetch_depth=self.prefetch_depth,
                dedupe_inflight=self.dedupe_inflight,
                dedupe_wait_seconds=self.dedupe_wait_seconds,
                share_sigs=share_sigs,
                worker_pool=self.worker_pool,
                cancel=cancel,
                chunk_plans=chunk_plans,
                # Planner chose COMPUTE although a load existed — loading
                # is costlier there; the dedupe shortcut must not undo it.
                dedupe_skip={n for n, s in states.items()
                             if s is State.COMPUTE
                             and load_cost.get(n) is not None})
        finally:
            for lease in read_leases:
                lease.release()

        # Record statistics for future iterations. Nodes the in-flight
        # dedupe turned into loads did not yield a compute measurement;
        # loads (planned or deduped) count as reuse events, which feed
        # OMP's amortization (see costs.py / omp.py multiplicity).
        for n, secs in report.runtime.items():
            if states[n] is State.COMPUTE and n not in report.deduped:
                self.cost_model.record(sigs[n], compute_seconds=secs)
            else:
                self.cost_model.record(sigs[n], reused=True)
        self.cost_model.save()
        self.iteration += 1

        evictions = {}
        if self.evictor is not None:
            after = self.evictor.stats.snapshot()
            evictions = {k: after[k] - ev_before.get(k, 0) for k in after}
        return IterationReport(
            execution=report, sigs=sigs, original=original,
            sliced_away=set(dag.nodes) - keep,
            store_bytes=self.store.total_bytes(), purged_bytes=purged,
            evictions=evictions)
