"""Chunk-partitioned values — incremental recomputation on data deltas.

Helix recomputes any node whose input signature changed, so appending one
data batch flips the source signature and recomputes entire subtrees: the
daily-retrain scenario gets zero reuse. Following "Spinning Fast Iterative
Data Flows" (PAPERS.md), this module makes materializations *partitioned*:
an append-mostly source declares per-chunk identities, chunk-level
signatures flow through operators that declared how they transform
per-chunk (``incremental=`` on :meth:`Workflow.node`), and the executor
recomputes only the chunks whose signatures it has never seen — splicing
them into cached per-chunk state.

Three operator capabilities are modeled (the classic incremental-dataflow
trio):

``"map"``
    Row-local: ``fn(concat(chunks)) == concat(fn(c) for c in chunks)``.
    Chunk ``j`` of the output depends only on chunk ``j`` of each chunked
    parent (non-chunked parents are broadcast whole). One-hot encoding and
    other per-row featurizers qualify; anything with global state (quantile
    bucketizers, standardizers) does not.
``"union"``
    Row-concatenation of its parents: the output's chunk list is the
    parents' chunk lists concatenated in parent order (``fn`` is never
    invoked on the incremental path — declaring ``union`` asserts the
    operator *is* concat).
``"assoc_reduce"``
    Associative aggregation: ``fn`` maps a chunk to a *partial* array and
    must satisfy ``fn(concat(chunks)) == fn(stack(partials))`` (sums,
    maxima, counts…). Cached partials combine with delta partials, so an
    append reduces only the new chunks. The node's output is the combined
    value — *not* chunked — so downstream consumers see a scalar world.

**Determinism contract.** Whenever a chunk plan exists for a node, the
executor computes it per-chunk *even on a cold store*. The result is then a
pure function of (chunk values, plan) — identical whether zero, some, or
all chunks came from cache — which is what makes the differential oracle's
bit-identity assertion (tests/test_incremental.py) hold exactly, including
for float reductions where a different summation order would drift ulps.

:class:`Chunked` is registered as a jax pytree so the store's host
snapshot, byte estimates, and blocking helpers traverse it transparently;
the store itself special-cases it *before* flattening to persist a
manifest + per-chunk entries (see store.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import numpy as np

from .dag import DAG, Kind

#: Chunk-plan modes (``ChunkPlan.mode``); "source" marks a chunked root.
MODES = ("source", "map", "union", "assoc_reduce")


def tree_concat(values: list) -> Any:
    """Concatenate a list of like-shaped pytrees leaf-wise along axis 0
    (arrays concat; a dict of columns concats per column)."""
    if len(values) == 1:
        return values[0]
    return jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
        *values)


def tree_stack(values: list) -> Any:
    """Stack a list of like-shaped pytrees leaf-wise along a new axis 0 —
    how assoc_reduce partials are fed back through ``fn`` to combine
    (``fn(concat(chunks)) == fn(stack(partials))``)."""
    return jax.tree_util.tree_map(
        lambda *xs: np.stack([np.asarray(x) for x in xs], axis=0), *values)


@dataclasses.dataclass
class Chunked:
    """A value carried as per-chunk pieces plus their chunk signatures.

    ``combine`` is ``"concat"`` (map/union/source chains: the logical
    value is the row-concatenation of ``chunks``) or ``"reduce"``
    (``chunks`` are assoc_reduce *partials* and ``final`` holds the
    combined output). :meth:`assemble` returns the logical value either
    way — opaque consumers always receive it assembled.
    """

    chunks: tuple
    chunk_sigs: tuple
    combine: str = "concat"
    final: Any = None

    def __post_init__(self) -> None:
        self.chunks = tuple(self.chunks)
        self.chunk_sigs = tuple(self.chunk_sigs)
        if len(self.chunks) != len(self.chunk_sigs):
            raise ValueError(
                f"{len(self.chunks)} chunks vs {len(self.chunk_sigs)} "
                "chunk signatures")
        if self.combine not in ("concat", "reduce"):
            raise ValueError(f"unknown combine {self.combine!r}")

    def __len__(self) -> int:
        return len(self.chunks)

    def assemble(self) -> Any:
        """The logical (un-partitioned) value this Chunked represents."""
        if self.combine == "reduce":
            return self.final
        return tree_concat(list(self.chunks))


def _flatten_chunked(c: Chunked):
    return (c.chunks, c.final), (c.chunk_sigs, c.combine)


def _unflatten_chunked(aux, children):
    chunks, final = children
    obj = object.__new__(Chunked)
    obj.chunks = tuple(chunks)
    obj.chunk_sigs = aux[0]
    obj.combine = aux[1]
    obj.final = final
    return obj


jax.tree_util.register_pytree_node(Chunked, _flatten_chunked,
                                   _unflatten_chunked)


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Per-node chunk-granular plan: how (and under which per-chunk
    signatures) the node's value partitions. Derived at planning time by
    :func:`compute_chunk_plans`; carried by the executor so the computed
    :class:`Chunked` always labels its pieces with plan signatures."""

    mode: str                       # one of MODES
    chunk_sigs: tuple               # per-chunk (or per-partial) signatures
    chunked_parents: tuple = ()     # parents that supply chunks

    @property
    def n_chunks(self) -> int:
        """Number of chunks (or reduce partials) this plan covers."""
        return len(self.chunk_sigs)


def _chunk_hash(*parts: str) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(str(p).encode())
        h.update(b"\x00")
    return h.hexdigest()


def compute_chunk_plans(dag: DAG, sigs: dict) -> dict:
    """Derive chunk-level signatures for every node they can flow to.

    Walks the DAG bottom-up, mirroring ``compute_signatures`` one level
    finer. A chunked *source* (``Node.chunk_ids`` set) seeds per-chunk
    signatures from its chunk identities — deliberately excluding the
    node ``version`` (which changes on every append; the chunk ids are
    what stay stable across appends). Downstream, a node joins the
    chunked world iff it declared an ``incremental`` capability, is
    deterministic, and its parents' plans are compatible:

    * ``map`` — at least one concat-mode chunked parent, all with equal
      chunk counts; chunk ``j``'s signature hashes the node identity,
      every chunked parent's chunk-``j`` signature and every non-chunked
      parent's *full* signature (so a change to broadcast state
      deprecates every chunk, exactly like the recursive full signature).
    * ``union`` — every parent chunked (concat mode); the chunk-signature
      list is the parents' lists concatenated.
    * ``assoc_reduce`` — exactly one concat-mode chunked parent; per-chunk
      *partial* signatures hash the node identity against the parent's
      chunk signatures. The plan's mode marks the output as not chunked
      (descendants fall back to whole-value signatures).

    Any node that fails these gates simply gets no plan — the executor
    then computes it whole from assembled parents, which is the paper's
    whole-subtree recompute fallback.
    """
    plans: dict = {}
    for name in dag.topological():
        node = dag.nodes[name]
        if not node.deterministic:
            continue
        if node.kind is Kind.SOURCE and node.chunk_ids:
            plans[name] = ChunkPlan(
                "source",
                tuple(_chunk_hash("chunk", name, node.kind.value, cid)
                      for cid in node.chunk_ids))
            continue
        inc = node.incremental
        if inc is None:
            continue
        cparents = tuple(p for p in node.parents
                         if p in plans and plans[p].mode != "assoc_reduce")
        if inc == "map":
            if not cparents:
                continue
            counts = {plans[p].n_chunks for p in cparents}
            if len(counts) != 1:
                continue
            others = tuple(sigs[p] for p in node.parents
                           if p not in cparents)
            csigs = tuple(
                _chunk_hash("chunk", name, node.kind.value, node.version,
                            *(plans[p].chunk_sigs[j] for p in cparents),
                            *others)
                for j in range(counts.pop()))
            plans[name] = ChunkPlan("map", csigs, cparents)
        elif inc == "union":
            if not node.parents or len(cparents) != len(node.parents):
                continue
            csigs = tuple(cs for p in node.parents
                          for cs in plans[p].chunk_sigs)
            plans[name] = ChunkPlan("union", csigs, cparents)
        elif inc == "assoc_reduce":
            if len(cparents) != 1:
                continue
            p0 = cparents[0]
            others = tuple(sigs[p] for p in node.parents if p != p0)
            csigs = tuple(
                _chunk_hash("partial", name, node.kind.value, node.version,
                            cs, *others)
                for cs in plans[p0].chunk_sigs)
            plans[name] = ChunkPlan("assoc_reduce", csigs, cparents)
        else:
            raise ValueError(
                f"{name}: unknown incremental capability {inc!r}; "
                f"expected one of {MODES[1:]} or None")
    return plans


def protected_chunk_sigs(chunk_plans: dict) -> frozenset:
    """Every chunk signature the upcoming execution may splice from.

    The §6.6 purge deletes *stale* manifests (same name, old full
    signature) before execution — but a delta's new manifest shares its
    prefix chunks with the manifest being purged. Passing this set as
    ``Store.delete(..., keep_chunks=...)`` keeps those still-valid
    sibling chunks on disk while the stale manifest itself goes."""
    return frozenset(cs for plan in chunk_plans.values()
                     for cs in plan.chunk_sigs)
