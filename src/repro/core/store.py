"""Content-addressed materialization store (paper's "materialization
operator" + Helix-JAX's distributed checkpoint substrate).

Entries are keyed by the node's *signature* (see signature.py), so a lookup
hit is exactly the paper's "equivalent materialization" (Def. 3). Values are
arbitrary pytrees whose array leaves may be sharded ``jax.Array``s.

Array leaves are persisted as ``.npy`` and reloaded with
``jax.make_array_from_callback`` against a **target sharding**, reading only
the slices each device needs (``np.load(mmap_mode='r')``). That means a value
materialized under mesh A can be restored under mesh B — the elastic-restart
path. Non-array leaves are pickled.

The store is safe for concurrent use by the pipelined executor *and* — new
in fleet mode — by many sessions sharing one workdir, whether sweep threads
in one process or independent OS processes:

* Every publish/delete of an entry happens under a **per-signature file
  lock** (``flock``; see locking.py), and removal renames the entry dir to
  a staging name before deleting it, so an entry atomically exists-whole or
  not-at-all from any process's point of view. Loads retry once if they
  race an overwrite.
* An **on-disk index** (``.fleet/index.json``) mirrors the entry set and is
  updated atomically together with each publish/delete (under the same
  per-signature lock), making ``entries()``/``total_bytes()`` one read
  instead of an O(entries) directory walk. A crash between dir-op and
  index-op is healed by the rebuild every ``Store.__init__`` performs.
* **Compute leases** (``acquire_compute`` / ``wait_compute``) give fleets
  in-flight dedupe: the first session to need a signature takes the lease
  and computes; others wait on it and load the published result. Leases
  are ``flock``s, so a crashed holder's lease evaporates with its process
  (stale-lease takeover for free). Waiters register marker files so the
  holder knows someone is blocked on the result and can force-persist it.
  **Read leases** (shared mode) pin entries a session plans to LOAD;
  ``delete`` probes the lease and skips entries other sessions still need.
* Entries carry **benefit metadata** for fleet eviction (eviction.py):
  cost-to-recompute ``compute_s`` and load-estimate ``load_s_est`` are
  persisted at save time (``extra_meta``), and every load bumps a
  ``loads`` count + ``last_load`` stamp in ``meta.json``
  (``_note_load``; mirrored to the index on power-of-two counts so the
  hot load path never serializes on the global index lock), so ranking
  a whole store is one index read. Overwrites carry the old entry's
  load evidence forward.
* Save/load wall-times feed a **merge-on-flush EWMA** bandwidth file
  (``.fleet/bw.json``) shared by all sessions — the cost model's ``l_i``
  estimates (paper §5.1: l_i = bytes / store bandwidth) improve fleet-wide
  instead of per-session.
* ``save_enqueue`` hands a host snapshot to a dedicated **writer thread**;
  in-flight bytes are bounded by ``max_inflight_bytes``. Multi-leaf values
  are written/read with per-leaf parallel .npy I/O (shared small pool).
* With a **remote tier** attached (``remote=``, see remote.py) the local
  store becomes a write-through / read-through cache of a fleet-shared
  object store: every local publish is uploaded asynchronously off a
  dedicated uploader thread (``upload_now`` forces it synchronously —
  the executor uses that for shared signatures so cross-host waiters
  find the entry the moment the compute lease releases); ``has`` /
  ``meta`` / ``load`` fall back to the remote tier on local miss, and a
  fetched entry is published into the local tier (the populate is
  ledger-adjusted so the fleet budget stays exact). Compute leases
  compose: the local ``flock`` dedupes within the host, a remote TTL
  lease object dedupes across hosts (heartbeat-renewed; expiry replaces
  flock's crash-release), and ``wait_compute`` polls the remote lease
  when the holder is another host. Planned-LOAD read pins extend to a
  remote TTL pin when the entry only exists remotely, so no host's
  remote eviction can yank another host's plan. If the remote backend
  errors, the tier degrades to local-only for a cool-down window — the
  host keeps working (docs/operations.md, failure modes).
* **Chunk-partitioned materializations** (chunks.py): saving a
  :class:`~repro.core.chunks.Chunked` value publishes each chunk as an
  ordinary signature-keyed entry (``is_chunk`` meta) plus a small
  *manifest* entry under the node's full signature whose ``chunked``
  meta lists the chunk signatures. Loading the manifest reassembles the
  chunks; deleting it cascades to chunks no other manifest references
  (``keep_chunks`` protects the chunks an upcoming delta will splice);
  ``gc_orphan_chunks`` reclaims chunks stranded by a crash between
  chunk publish and manifest publish (the manifest is the commit point:
  readers never see a partial splice). Ledger accounting stays per
  chunk — every ``SaveInfo``/``delete`` byte count is exactly the bytes
  that appeared on or left the disk, so ledger == disk is preserved.
  Chunked entries are local-tier only (manifests and chunks are not
  uploaded to the remote tier).
* **TierStack** (memory → disk → remote): with ``mem_budget_bytes`` a
  bounded host-RAM tier (memtier.py) sits in front of the disk tier
  behind the same signature-keyed API. Every publish write-through
  admits its host snapshot; every disk/remote load read-through
  promotes its value; a same-process reload is then a zero-copy pytree
  handoff — no ``.npy`` read, no unpickle (sharded loads re-place
  leaves with ``jax.device_put`` and offload device arrays to host
  asynchronously on the writer queue). The memory budget is enforced by
  *demote-not-delete* eviction ranked by ``eviction.ranked_mem``; with
  ``mem_writeback=True`` saves land memory-only (``SaveInfo.nbytes`` is
  0 until demotion spills them to disk through the
  ``memtier:before_spill`` / ``memtier:after_spill`` crash points, at
  which point the bytes are ledger-adjusted in). ``est_load_seconds``
  prices the cheapest tier that can serve a signature via per-tier EWMA
  bandwidths (``costs.TierBandwidth`` over the same ``.fleet/bw.json``)
  and ``tier_status`` reports one unified per-tier record.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import pickle
import shutil
import threading
import time
import uuid
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

import jax

from .chunks import Chunked
from .costs import TierBandwidth
from .locking import (FileLock, SharedEwma, StorageLedger, read_json,
                      update_json)
from .memtier import MemEntry, MemTier
from .remote import RemoteStore


@dataclasses.dataclass
class SaveInfo:
    nbytes: int
    seconds: float
    # True when this save overwrote an existing entry for the signature —
    # the caller's budget reservation then double-counts a value already
    # paid for (e.g. two sessions raced the same signature) and should be
    # credited back.
    replaced: bool = False
    # Recorded on-disk size of the entry this save replaced (0 when
    # ``replaced`` is False). The bytes an overwrite frees are the *old*
    # entry's bytes, not the new reservation — budget accounting must
    # credit this number, or the shared ledger drifts from disk whenever
    # the two sizes differ.
    replaced_nbytes: int = 0


class PendingSave:
    """Handle for a queued write. ``result()`` blocks until the writer has
    persisted the entry and returns its :class:`SaveInfo`; ``join()`` is
    kept for drop-in compatibility with the old thread-based API."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._info: SaveInfo | None = None
        self._error: BaseException | None = None

    def _finish(self, info: SaveInfo | None,
                error: BaseException | None = None) -> None:
        self._info = info
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> SaveInfo:
        if not self._event.wait(timeout):
            raise TimeoutError("materialization write still pending")
        if self._error is not None:
            raise self._error
        assert self._info is not None
        return self._info

    def join(self, timeout: float | None = None) -> None:
        self._event.wait(timeout)


def _leaf_to_host(leaf: Any) -> Any:
    if isinstance(leaf, jax.Array):
        return np.asarray(jax.device_get(leaf))
    return leaf


def tree_nbytes(value: Any) -> int:
    """Pre-save storage estimate for a pytree (used by OMP's budget)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, (jax.Array, np.ndarray)):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        else:
            total += 64  # nominal
    return total


# Leaves smaller than this are not worth a pool round-trip.
_PARALLEL_LEAF_MIN_BYTES = 1 << 20

_io_pool: ThreadPoolExecutor | None = None
_io_pool_lock = threading.Lock()


def _leaf_io_pool() -> ThreadPoolExecutor:
    """Small process-wide pool for per-leaf .npy reads/writes."""
    global _io_pool
    with _io_pool_lock:
        if _io_pool is None:
            _io_pool = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="store-leaf-io")
        return _io_pool


def _npy_storage_view(leaf: np.ndarray) -> np.ndarray:
    """Reinterpret ml_dtypes leaves (bf16, fp8…) as plain uints for .npy."""
    if leaf.dtype.kind in "biufc":
        return leaf
    return leaf.view({1: np.uint8, 2: np.uint16, 4: np.uint32}
                     [leaf.dtype.itemsize])


class ComputeLease:
    """Exclusive right to compute one signature fleet-wide.

    Held from just before the compute starts until the value is either
    published to the store or the holder decides not to persist it. The
    kernel releases the underlying ``flock`` if the holder crashes, so
    waiters take over stale leases automatically. With a remote tier the
    lease spans both scopes: the local ``flock`` excludes this host's
    sessions, a heartbeat-renewed remote TTL lease excludes other hosts
    (its *expiry* is the cross-host crash-release).
    """

    def __init__(self, store: "Store", sig: str, lock: FileLock,
                 remote_lease=None):
        self._store = store
        self.sig = sig
        self._lock: FileLock | None = lock
        self._remote_lease = remote_lease

    def waiters(self) -> int:
        """How many sessions are currently blocked on this signature
        (this host's waiter markers plus remote hosts' TTL markers)."""
        return self._store._count_waiters(self.sig)

    def release(self) -> None:
        # Remote first: a cross-host waiter that wakes on the remote
        # lease vanishing must already be able to see the published
        # entry (upload_now ran before release on shared paths).
        if self._remote_lease is not None:
            self._remote_lease.release()
            self._remote_lease = None
        if self._lock is not None:
            self._lock.release()
            self._lock = None

    def __enter__(self) -> "ComputeLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ReadPin:
    """A held planned-LOAD pin spanning tiers.

    Wraps the local shared ``flock`` (blocks this host's eviction) and,
    when the entry only exists remotely, a remote TTL pin (blocks every
    host's remote eviction until the load lands)."""

    def __init__(self, lock: FileLock, remote_pin=None):
        self._lock: FileLock | None = lock
        self._remote_pin = remote_pin

    def release(self) -> None:
        """Drop both pins (idempotent)."""
        if self._remote_pin is not None:
            self._remote_pin.release()
            self._remote_pin = None
        if self._lock is not None:
            self._lock.release()
            self._lock = None


# Workdir roots this process has already healed (scan + index rebuild +
# metadata reap). A sweep opens K Stores on one root; only the first pays
# the O(entries) scan. A fresh process (the crash-recovery case) always
# heals on its first open.
_healed_roots: set[str] = set()
_healed_roots_lock = threading.Lock()


class Store:
    _tmp_counter = itertools.count()

    def __init__(self, root: str, max_inflight_bytes: int = 1 << 30,
                 heal: bool | None = None,
                 remote: RemoteStore | None = None,
                 mem_budget_bytes: float = 0.0,
                 mem_writeback: bool = False):
        """``heal`` controls the open-time crash recovery (stale-staging
        reap, fleet-metadata reap, index rebuild from a directory scan):
        None (default) runs it on the first open of this root in this
        process only; True forces it; False skips it. ``remote`` attaches
        a fleet-shared :class:`~repro.core.remote.RemoteStore` tier the
        local store write-through/read-through caches (see remote.py).
        ``mem_budget_bytes`` > 0 attaches the memory tier (memtier.py):
        a bounded process-local host-RAM cache of materialized values in
        front of the disk tier; ``mem_writeback`` makes saves land
        memory-only until demotion spills them (write-back mode)."""
        self.root = root
        self.remote = remote
        os.makedirs(root, exist_ok=True)
        os.makedirs(self._fleet_dir("locks"), exist_ok=True)
        os.makedirs(self._fleet_dir("leases"), exist_ok=True)
        if heal is None:
            key = os.path.realpath(root)
            with _healed_roots_lock:
                heal = key not in _healed_roots
                _healed_roots.add(key)
        # merge-on-flush measured bandwidth (bytes/s), shared fleet-wide
        self._bw = SharedEwma(self._fleet_dir("bw.json"))
        # dedicated writer queue (overlapped materialization)
        self.max_inflight_bytes = int(max_inflight_bytes)
        self._writer_cv = threading.Condition()
        self._writer_queue: deque = deque()
        self._writer_thread: threading.Thread | None = None
        self._inflight_bytes = 0
        # dedicated remote uploader (write-through off the critical path)
        self._upload_cv = threading.Condition()
        self._upload_queue: deque = deque()
        self._upload_thread: threading.Thread | None = None
        self._uploads_inflight = 0
        # local loads served by a remote fetch (read-through populates)
        self.remote_hits = 0
        # Optional fault-injection plan (faults.FaultPlan): consulted at
        # the named crash points of the chunked-splice publish path
        # (``splice:chunk_published``, ``splice:before_manifest``) and
        # the memory tier's demotion path (``memtier:before_spill``,
        # ``memtier:after_spill``). Production runs leave it None and
        # pay one ``is None`` check.
        self.faults = None
        # Per-tier EWMA bandwidths over the shared bw.json (the disk
        # tier keeps the legacy read/write keys, so old files stay
        # valid and no-sig estimates are numerically unchanged).
        self._tier_bw = TierBandwidth(self._bw)
        # Per-tier load accounting + the .npy leaf-read counter the
        # zero-serialization-on-hit guarantee is asserted against.
        self.load_stats = {
            "memory": {"hits": 0, "misses": 0, "bytes": 0},
            "local": {"hits": 0, "misses": 0, "bytes": 0},
            "remote": {"hits": 0, "misses": 0, "bytes": 0},
        }
        self.npy_leaf_reads = 0
        # load() runs concurrently on executor worker threads; bare
        # ``+=`` on these counters drops increments under contention
        # (read-modify-write races), which the tenant stress harness
        # observes as tier_status() hit counts drifting from truth.
        self._stats_lock = threading.Lock()
        # Signatures whose disk write the writer thread currently owns
        # (popped from the queue, save not yet landed): a memory-tier
        # spill of such a signature may drop instead of double-saving.
        self._writer_active: set[str] = set()
        # Memory tier (TierStack head). 0 budget = no tier: every
        # existing direct-Store caller keeps the two-tier behavior.
        self._mem: MemTier | None = None
        if mem_budget_bytes and mem_budget_bytes > 0:
            self._mem = MemTier(
                mem_budget_bytes, writeback=mem_writeback,
                spill=self._spill_from_mem,
                offload=self._mem_offload_enqueue,
                est_disk_load=lambda nb:
                    self._tier_bw.est_load_seconds("local", nb))
        if heal:
            self._reap_stale_tmp()
            self._reap_fleet_metadata()
            # Heal the index after crashes (a process dying between
            # dir-op and index-op leaves them out of sync; the scan is
            # ground truth).
            self.rebuild_index()

    # A staging dir older than this is an orphan even if we cannot tell
    # whether its owner pid is alive (e.g. it came from another host).
    _TMP_ORPHAN_SECONDS = 3600.0

    @staticmethod
    def _tmp_is_orphan(path: str, name: str) -> bool:
        """A staging dir is an orphan iff its owning process is provably
        dead, or it is old enough that no live save can still be writing
        it. Opening a store while sibling processes are mid-save must NOT
        reap their live staging dirs."""
        try:
            pid = int(name.split(".tmp-", 1)[1].removeprefix("del-")
                      .split("-", 1)[0] or 0)
        except (IndexError, ValueError):
            pid = 0
        if pid > 0:
            try:
                os.kill(pid, 0)
            except ProcessLookupError:
                return True      # owner is gone (same host)
            except PermissionError:
                pass             # alive, not ours
        try:
            age = time.time() - os.stat(path).st_mtime
        except OSError:
            return False         # vanished already (owner cleaned it up)
        return age > Store._TMP_ORPHAN_SECONDS

    def _reap_stale_tmp(self) -> None:
        """Remove staging dirs orphaned by a crash mid-save. They contain a
        meta.json, so without this sweep a directory rescan would count
        them as phantom entries forever."""
        for sub in os.listdir(self.root):
            subdir = os.path.join(self.root, sub)
            if sub.startswith(".") or not os.path.isdir(subdir):
                continue
            for name in os.listdir(subdir):
                path = os.path.join(subdir, name)
                if ".tmp-" in name and self._tmp_is_orphan(path, name):
                    shutil.rmtree(path, ignore_errors=True)

    def _reap_fleet_metadata(self) -> None:
        """Prune per-signature lock/lease files for long-gone entries,
        dead waiter markers, and orphaned atomic-publish temp files.
        Without this a long-lived workdir accumulates one zero-byte file
        per signature ever seen (and _count_waiters listdirs leases/ on
        every lease-compute). Unlinking a lock file is safe because
        FileLock.acquire verifies it locked the inode the path names."""
        fleet = self._fleet_dir()
        for name in os.listdir(fleet):
            path = os.path.join(fleet, name)
            if ".tmp-" in name and os.path.isfile(path) \
                    and self._tmp_is_orphan(path, name):
                try:
                    os.unlink(path)   # update_json crash leftovers
                except OSError:
                    pass
        now = time.time()
        for sub, suffix in (("locks", ".lock"), ("leases", ".lease")):
            d = self._fleet_dir(sub)
            for name in os.listdir(d):
                path = os.path.join(d, name)
                if sub == "leases" and ".w-" in name:
                    if self._waiter_is_dead(path):
                        try:
                            os.unlink(path)
                        except OSError:
                            pass
                    continue
                if not name.endswith(suffix):
                    continue
                sig = name[: -len(suffix)]
                try:
                    age = now - os.stat(path).st_mtime
                except OSError:
                    continue
                # Cold (no one can be mid-save) and entry-less: reap
                # under the exclusive lock so no live holder is split.
                # Local-tier check only: lock/lease files guard local
                # publishes; a remote-only entry needs no local lock.
                if age <= self._TMP_ORPHAN_SECONDS or self.has_local(sig):
                    continue
                guard = FileLock(path)
                if guard.acquire(blocking=False):
                    try:
                        if not self.has_local(sig):
                            try:
                                os.unlink(path)
                            except OSError:
                                pass
                    finally:
                        guard.release()

    # -- paths ---------------------------------------------------------------
    def _dir(self, sig: str) -> str:
        return os.path.join(self.root, sig[:2], sig)

    def _fleet_dir(self, *parts: str) -> str:
        return os.path.join(self.root, ".fleet", *parts)

    def _entry_lock(self, sig: str) -> FileLock:
        return FileLock(self._fleet_dir("locks", f"{sig}.lock"))

    def _lease_path(self, sig: str) -> str:
        return os.path.join(self._fleet_dir("leases"), f"{sig}.lease")

    @property
    def ledger_path(self) -> str:
        """Path of the fleet-shared storage-budget ledger for this store."""
        return self._fleet_dir("ledger.json")

    @property
    def index_path(self) -> str:
        return self._fleet_dir("index.json")

    def has_local(self, sig: str) -> bool:
        """Entry present in the local tier (one stat)."""
        return os.path.exists(os.path.join(self._dir(sig), "meta.json"))

    def computing(self, sig: str) -> bool:
        """Is an exclusive compute lease held on ``sig`` right now?

        A non-blocking flock probe of the signature's lease file: True
        means some session is mid-compute of this value. Advisory
        observability (the server's marginal-cost estimate counts live
        leaders with it) — never a synchronization primitive; the lease
        can change hands the instant this returns."""
        return FileLock(self._lease_path(sig)).probe() == "exclusive"

    def mem_has(self, sig: str) -> bool:
        """Resident in the memory tier right now (False without one).
        Observability + tier pricing; like :meth:`computing`, never a
        synchronization primitive."""
        return self._mem is not None and self._mem.has(sig)

    def has(self, sig: str) -> bool:
        """Entry reachable on any tier: local disk, memory-resident
        (possibly memory-only in write-back mode — still loadable
        in-process), or committed in the remote tier (loadable through
        the read-through fetch path). This is the planner's reuse test.
        Remote presence may be cached a couple of seconds;
        dedupe-critical paths use :meth:`has_fresh`."""
        if self.has_local(sig):
            return True
        if self._mem is not None and self._mem.has(sig):
            return True
        return self.remote is not None and self.remote.exists(sig)

    def has_fresh(self, sig: str) -> bool:
        """Presence check that bypasses the remote marker cache.

        The executor calls this *after acquiring a compute lease*: a
        stale cached negative there would recompute a value another host
        committed moments ago — the lease acquisition is the natural
        point to pay one uncached probe for exact fleet-wide
        compute-once. (Also refreshes the cache, so the caller's
        follow-up ``has``/``load`` sees the entry.)"""
        if self.has_local(sig):
            return True
        if self._mem is not None and self._mem.has(sig):
            return True
        return (self.remote is not None
                and self.remote.marker_meta(sig, fresh=True) is not None)

    @staticmethod
    def _rewrite_json(path: str, obj: dict) -> bool:
        """Atomically replace the JSON file at ``path`` via a staged
        sibling + ``os.replace`` (readers only ever see a whole file; a
        failed write — ENOSPC… — leaves the original intact and cleans
        the staging file). Returns False on failure — callers treat the
        rewrite as best-effort."""
        tmp = f"{path}.{os.getpid()}-{threading.get_ident()}"
        try:
            with open(tmp, "w") as f:
                json.dump(obj, f)
            os.replace(tmp, path)
            return True
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False

    # -- save ------------------------------------------------------------------
    def _crash_point(self, point: str) -> None:
        """Consult the attached fault plan (no-op without one)."""
        if self.faults is not None:
            self.faults.crash_point(point)

    def save(self, sig: str, name: str, value: Any,
             extra_meta: dict | None = None, *,
             _tier_admit: bool = True) -> SaveInfo:
        if isinstance(value, Chunked):
            return self._save_chunked(sig, name, value, extra_meta)
        t0 = time.perf_counter()
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        extra = extra_meta or {}
        if (self._mem is not None and self._mem.writeback and _tier_admit
                and not extra.get("is_chunk") and "chunked" not in extra):
            # Write-back mode: the save lands in the memory tier only;
            # the disk write happens at demotion (_spill_from_mem) or an
            # explicit mem_flush(). nbytes=0 keeps the caller's budget
            # ledger equal to on-disk bytes — the spill adjusts the
            # bytes in when they actually land. Chunk entries and
            # manifests always write through: the manifest commit point
            # must never reference chunks another process cannot read.
            wb_nbytes = tree_nbytes(host_value)
            wb_meta = {"name": name, "sig": sig, "nbytes": wb_nbytes,
                       "created": time.time()}
            wb_meta.update(extra)
            if self._mem.put(sig, host_value, wb_nbytes, name=name,
                             meta=wb_meta, state="dirty"):
                return SaveInfo(nbytes=0,
                                seconds=time.perf_counter() - t0)
            # Value exceeds the whole memory budget — write through.
        d = self._dir(sig)
        # Unique temp dir: concurrent saves of one signature must not
        # clobber each other's staging area (last publish wins below).
        tmp = (f"{d}.tmp-{os.getpid()}-{threading.get_ident()}"
               f"-{next(self._tmp_counter)}")
        os.makedirs(tmp, exist_ok=True)
        try:
            manifest, nbytes = self._write_leaves(tmp, host_value)
            seconds = time.perf_counter() - t0
            meta = {
                "name": name, "sig": sig, "nbytes": nbytes,
                "save_seconds": seconds, "created": time.time(),
                "manifest": manifest,
            }
            meta.update(extra_meta or {})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            # Publish + index update as one per-signature transaction, so
            # the index never disagrees with the directory for a signature
            # (concurrent save/delete of one sig serialize here).
            with self._entry_lock(sig):
                replaced = os.path.exists(d)
                replaced_nbytes = 0
                if replaced:
                    try:
                        with open(os.path.join(d, "meta.json")) as f:
                            old_meta = json.load(f)
                    except (OSError, json.JSONDecodeError):
                        old_meta = {}
                    try:
                        replaced_nbytes = int(old_meta.get("nbytes", 0))
                    except (ValueError, TypeError):
                        replaced_nbytes = 0
                    # Carry the observed-reuse evidence forward: an
                    # overwrite (same signature ⇒ same value) must not
                    # reset the entry's load count, or the fleet's
                    # hottest entry ranks as cold for eviction right
                    # after two sessions race a save. Best-effort and
                    # crash-safe: the rewrite goes through a sibling
                    # temp + os.replace, so a failed write (ENOSPC…)
                    # leaves the already-staged meta.json whole and
                    # only drops the carried counters.
                    carried = {k: old_meta[k]
                               for k in ("loads", "last_load")
                               if k in old_meta}
                    if carried:
                        new_meta = dict(meta, **carried)
                        if self._rewrite_json(os.path.join(tmp,
                                                           "meta.json"),
                                              new_meta):
                            meta = new_meta
                    self._retire_dir(d)
                os.rename(tmp, d)
                self._index_apply(add={sig: self._index_entry(meta)})
            self._update_bw("write", nbytes, seconds)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if self._mem is not None and _tier_admit and not meta.get("chunked"):
            # Write-through admission into the memory tier: the host
            # snapshot is already in hand, so promoting it is free and
            # makes the next same-process load a pointer handoff.
            self._mem.put(sig, host_value, nbytes, name=name, meta=meta,
                          state="durable")
        # Write-through: hand the published entry to the uploader (async
        # — off both the caller and the writer queue's drain path; after
        # the try so a queueing hiccup can't mis-report a landed save).
        # Shared-signature saves additionally upload_now() before their
        # compute lease releases (executor).
        self._enqueue_upload(sig, meta)
        return SaveInfo(nbytes=nbytes, seconds=seconds, replaced=replaced,
                        replaced_nbytes=replaced_nbytes)

    def _save_chunked(self, sig: str, name: str, value: Chunked,
                      extra_meta: dict | None = None) -> SaveInfo:
        """Publish a partitioned materialization: per-chunk entries first,
        then the manifest under the node's full signature.

        The manifest is the *commit point* — until it publishes, readers
        see nothing (``has(sig)`` is false), so a crash mid-splice leaves
        only orphan chunk entries for :meth:`gc_orphan_chunks` and a
        retry republishes bit-identically (chunks are content-addressed;
        already-present ones are skipped, not rewritten). The returned
        ``SaveInfo.nbytes`` counts exactly the bytes this call added to
        disk (new chunks + manifest), which is what keeps the fleet
        ledger equal to on-disk bytes."""
        t0 = time.perf_counter()
        new_bytes = 0
        chunk_bytes = 0
        try:
            for csig, chunk in zip(value.chunk_sigs, value.chunks):
                if self.has_local(csig):
                    try:
                        chunk_bytes += int(self.meta(csig).get("nbytes", 0))
                        continue
                    except (FileNotFoundError, json.JSONDecodeError):
                        pass  # raced a delete — republish below
                info = self.save(csig, f"{name}#chunk", chunk,
                                 extra_meta={"is_chunk": True})
                new_bytes += info.nbytes
                if info.replaced:
                    new_bytes -= info.replaced_nbytes
                chunk_bytes += info.nbytes
                self._crash_point("splice:chunk_published")
            self._crash_point("splice:before_manifest")
            extra = dict(extra_meta or {})
            extra["chunked"] = {"combine": value.combine,
                                "chunk_sigs": list(value.chunk_sigs),
                                "chunk_bytes": chunk_bytes}
            # Reduce manifests carry the combined value as their own
            # payload (loading one returns the final value directly);
            # concat manifests carry no payload — their value *is* the
            # chunk set.
            payload = value.final if value.combine == "reduce" else ()
            info = self.save(sig, name, payload, extra_meta=extra)
        except BaseException:
            # The chunks published so far are committed entries that stay
            # on disk (a retry dedupes them; gc_orphan_chunks reclaims
            # them if no retry comes), but the caller releases its whole
            # reservation on failure — adjust their bytes in so the fleet
            # ledger keeps mirroring the disk (the same honesty-over-
            # overshoot call as the read-through populate).
            if new_bytes and os.path.exists(self.ledger_path):
                StorageLedger(self.ledger_path).adjust(float(new_bytes))
            raise
        return SaveInfo(nbytes=new_bytes + info.nbytes,
                        seconds=time.perf_counter() - t0,
                        replaced=info.replaced,
                        replaced_nbytes=info.replaced_nbytes)

    def _retire_dir(self, d: str) -> None:
        """Crash-safe removal: rename the entry dir to a staging name (so
        it atomically stops being an entry) before deleting its contents.
        A crash mid-rmtree leaves only a ``.tmp-`` dir for the reaper."""
        trash = (f"{d}.tmp-del-{os.getpid()}-{threading.get_ident()}"
                 f"-{next(self._tmp_counter)}")
        os.rename(d, trash)
        shutil.rmtree(trash, ignore_errors=True)

    def _write_leaves(self, tmp: str, host_value: Any) -> tuple[list, int]:
        leaves, treedef = jax.tree_util.tree_flatten(host_value)
        manifest: list[dict] = []
        nbytes = 0
        array_jobs: list[tuple[str, np.ndarray]] = []
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, np.ndarray):
                fn = f"leaf_{i}.npy"
                manifest.append({"kind": "array", "file": fn,
                                 "shape": list(leaf.shape),
                                 "dtype": str(leaf.dtype)})
                nbytes += leaf.nbytes
                array_jobs.append((os.path.join(tmp, fn), leaf))
            else:
                fn = f"leaf_{i}.pkl"
                with open(os.path.join(tmp, fn), "wb") as f:
                    pickle.dump(leaf, f)
                manifest.append({"kind": "pickle", "file": fn})
                nbytes += os.path.getsize(os.path.join(tmp, fn))

        def write_one(job):
            path, leaf = job
            np.save(path, _npy_storage_view(leaf), allow_pickle=False)

        big = [j for j in array_jobs
               if j[1].nbytes >= _PARALLEL_LEAF_MIN_BYTES]
        if len(big) >= 2:
            list(_leaf_io_pool().map(write_one, array_jobs))
        else:
            for job in array_jobs:
                write_one(job)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        return manifest, nbytes

    # -- writer queue ------------------------------------------------------------
    def save_enqueue(self, sig: str, name: str, value: Any,
                     extra_meta: dict | None = None) -> PendingSave:
        """Queue a write on the store's dedicated writer thread.

        The device→host snapshot happens synchronously (cheap, and it frees
        the caller to evict the value); the disk write runs off the critical
        path. Blocks while the writer's in-flight bytes exceed
        ``max_inflight_bytes`` so queued materializations cannot exhaust
        host memory.
        """
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        est = tree_nbytes(host_value)
        pending = PendingSave()
        if (self._mem is not None and not isinstance(value, Chunked)
                and not (extra_meta or {}).get("is_chunk")):
            # Admit before the disk write lands ("queued": the writer
            # thread owns a durable copy in flight, so demotion may drop
            # freely) — in-process reuse never waits on the writer.
            q_meta = {"name": name, "sig": sig, "nbytes": est,
                      "created": time.time()}
            q_meta.update(extra_meta or {})
            self._mem.put(sig, host_value, est, name=name, meta=q_meta,
                          state="queued")
        with self._writer_cv:
            while (self._inflight_bytes > 0
                   and self._inflight_bytes + est > self.max_inflight_bytes):
                self._writer_cv.wait()
            self._inflight_bytes += est
            self._writer_queue.append(
                ("save", sig, name, host_value, extra_meta, est, pending))
            if self._writer_thread is None or not self._writer_thread.is_alive():
                self._writer_thread = threading.Thread(
                    target=self._writer_loop, name="store-writer", daemon=True)
                self._writer_thread.start()
            self._writer_cv.notify_all()
        return pending

    def _writer_loop(self) -> None:
        while True:
            with self._writer_cv:
                if not self._writer_queue:
                    # Exit when idle; save_enqueue restarts the thread on
                    # demand, so an idle Store pins no thread for life.
                    self._writer_thread = None
                    return
                item = self._writer_queue.popleft()
                if item[0] == "save":
                    self._writer_active.add(item[1])
            if item[0] == "offload":
                # Async device→host snapshot of a memory-tier entry
                # (zero-copy sharded loads admit jax.Arrays; this moves
                # them off-device off the critical path).
                try:
                    self._mem_offload_run(item[1])
                except Exception:
                    pass   # advisory: the device copy keeps serving
                with self._writer_cv:
                    self._writer_cv.notify_all()
                continue
            _, sig, name, host_value, extra_meta, est, pending = item
            try:
                info = self.save(sig, name, host_value,
                                 extra_meta=extra_meta)
                pending._finish(info)
            except BaseException as e:
                pending._finish(None, e)
            with self._writer_cv:
                self._writer_active.discard(sig)
                self._inflight_bytes -= est
                self._writer_cv.notify_all()

    def save_async(self, sig: str, name: str, value: Any,
                   extra_meta: dict | None = None) -> PendingSave:
        """Deprecated alias for :meth:`save_enqueue` (kept for callers that
        still ``.join()`` the returned handle)."""
        return self.save_enqueue(sig, name, value, extra_meta=extra_meta)

    def writer_drain(self) -> None:
        """Block until every queued write has been persisted — and, with
        a remote tier, until every queued upload has settled too (writes
        enqueue their own uploads, so draining one without the other
        would leave the write-through half-done)."""
        with self._writer_cv:
            while self._writer_queue or self._inflight_bytes > 0:
                self._writer_cv.wait()
        self.remote_drain()

    # -- memory tier (TierStack head) --------------------------------------
    def _mem_offload_enqueue(self, sig: str) -> None:
        """Schedule an async device→host offload of a resident memory-
        tier entry on the writer queue — the same dedicated thread (and
        the same ``writer_drain`` barrier) that owns every other
        off-critical-path materialization write."""
        with self._writer_cv:
            self._writer_queue.append(("offload", sig))
            if self._writer_thread is None \
                    or not self._writer_thread.is_alive():
                self._writer_thread = threading.Thread(
                    target=self._writer_loop, name="store-writer",
                    daemon=True)
                self._writer_thread.start()
            self._writer_cv.notify_all()

    def _mem_offload_run(self, sig: str) -> None:
        """Writer-thread body of one offload: snapshot the entry's
        device arrays to host and swap the snapshot in (a racing
        re-admit of the signature wins — the swap is compare-and-set on
        the exact pytree the snapshot was taken from)."""
        if self._mem is None:
            return
        ent = self._mem.peek(sig)
        if ent is None or not ent.has_device:
            return
        device_value = ent.value
        host_value = jax.tree_util.tree_map(_leaf_to_host, device_value)
        self._mem.replace_value(sig, host_value, expect=device_value)

    def _spill_from_mem(self, sig: str, ent: MemEntry) -> None:
        """Demote one dirty (memory-only) entry to the disk tier.

        Called by the memory tier with the entry already removed from
        residency. Skips the write when a durable copy already exists or
        the writer queue owns one in flight — dropping is then free. The
        landed bytes are adjusted into the fleet ledger: nobody reserved
        them, but they are on disk, and ledger==disk outranks momentary
        overshoot (the same honesty call as the read-through populate).

        Crash points frame the torn-demotion window:
        ``memtier:before_spill`` dies with nothing (or only a staging
        ``.tmp-`` dir, reaped at the next heal) on disk — the entry
        vanishes with the process, and recovery is a clean recompute
        because no other process ever saw the signature;
        ``memtier:after_spill`` dies with the entry committed and the
        ledger already adjusted — nothing left to redo."""
        with self._writer_cv:
            queued = sig in self._writer_active or any(
                it[0] == "save" and it[1] == sig
                for it in self._writer_queue)
        if queued or self.has_local(sig):
            return
        self._crash_point("memtier:before_spill")
        extra = {k: v for k, v in ent.meta.items()
                 if k not in ("name", "sig", "nbytes", "save_seconds",
                              "created", "manifest")}
        info = self.save(sig, ent.name or "spill", ent.value,
                         extra_meta=extra, _tier_admit=False)
        if info.nbytes and not info.replaced \
                and os.path.exists(self.ledger_path):
            StorageLedger(self.ledger_path).adjust(float(info.nbytes))
        self._crash_point("memtier:after_spill")

    def mem_flush(self) -> int:
        """Write-back barrier: spill every dirty memory-tier entry to
        disk (no-op without the tier). Returns the number spilled."""
        return self._mem.flush() if self._mem is not None else 0

    # -- remote tier (write-through / read-through) ------------------------
    def _enqueue_upload(self, sig: str, meta: dict) -> None:
        """Queue one published entry for async upload to the remote
        tier (no-op without one, or while it is degraded). Chunked
        manifests and chunk entries stay in the local tier: a manifest
        names chunk signatures by reference, so shipping it without a
        transactional multi-entry upload would let a remote reader see
        a manifest whose chunks don't exist — a documented local-tier
        limitation for now."""
        if self.remote is None or not self.remote.available():
            return
        if meta.get("chunked") or meta.get("is_chunk"):
            return
        with self._upload_cv:
            self._upload_queue.append((sig, meta))
            self._uploads_inflight += 1
            if self._upload_thread is None \
                    or not self._upload_thread.is_alive():
                self._upload_thread = threading.Thread(
                    target=self._upload_loop, name="store-uploader",
                    daemon=True)
                self._upload_thread.start()
            self._upload_cv.notify_all()

    def _upload_loop(self) -> None:
        while True:
            with self._upload_cv:
                if not self._upload_queue:
                    # Exit when idle; _enqueue_upload restarts on demand.
                    self._upload_thread = None
                    return
                sig, meta = self._upload_queue.popleft()
            try:
                self.remote.upload(sig, self._dir(sig), meta)
            except BaseException:
                pass   # upload is best-effort; degradation is handled
            with self._upload_cv:
                self._uploads_inflight -= 1
                self._upload_cv.notify_all()

    def upload_now(self, sig: str) -> bool:
        """Synchronously write-through one published entry.

        The executor calls this for shared signatures *before* releasing
        the compute lease, so a cross-host waiter that wakes on the
        lease vanishing finds the entry committed — the async uploader
        alone would open a recompute window. Idempotent (a committed
        entry is skipped); False without a remote tier, on local miss,
        or when the upload was refused/degraded."""
        if self.remote is None:
            return False
        try:
            with open(os.path.join(self._dir(sig), "meta.json")) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        if meta.get("chunked") or meta.get("is_chunk"):
            return False   # chunked entries are local-tier only

        with self._upload_cv:
            # The save that published this entry already queued an async
            # upload; cancel it so the entry's bytes don't cross the
            # wire twice (the queue copy would pass the marker check
            # whenever it starts before this synchronous one commits).
            kept = deque(item for item in self._upload_queue
                         if item[0] != sig)
            dropped = len(self._upload_queue) - len(kept)
            if dropped:
                self._upload_queue = kept
                self._uploads_inflight -= dropped
                self._upload_cv.notify_all()
        return self.remote.upload(sig, self._dir(sig), meta)

    def remote_drain(self) -> None:
        """Block until the upload queue is empty (no-op without a
        remote tier)."""
        if self.remote is None:
            return
        with self._upload_cv:
            while self._upload_queue or self._uploads_inflight > 0:
                self._upload_cv.wait()

    def _fetch_remote(self, sig: str) -> bool:
        """Read-through: fetch ``sig`` from the remote tier and publish
        it into the local tier. Returns False when the entry is absent
        remotely (or the tier is degraded). The populate is accounted:
        when a fleet budget ledger exists, the entry's bytes are
        adjusted in — nobody reserved them, but they are on disk, and
        the ledger==disk invariant outranks momentary overshoot (the
        next admission's evict-to-fit sees honest occupancy)."""
        if self.remote is None:
            return False
        d = self._dir(sig)
        tmp = (f"{d}.tmp-{os.getpid()}-{threading.get_ident()}"
               f"-{next(self._tmp_counter)}")
        t0 = time.perf_counter()
        meta = self.remote.fetch(sig, tmp)
        fetch_seconds = time.perf_counter() - t0
        if meta is None:
            with self._stats_lock:
                self.load_stats["remote"]["misses"] += 1
            shutil.rmtree(tmp, ignore_errors=True)
            return False
        published = False
        with self._entry_lock(sig):
            if os.path.exists(d):
                # A sibling's fetch (or save) published first — ours is
                # redundant, theirs is equivalent (same signature).
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                os.rename(tmp, d)
                self._index_apply(add={sig: self._index_entry(meta)})
                published = True
        if published:
            nbytes = int(meta.get("nbytes", 0) or 0)
            with self._stats_lock:
                self.remote_hits += 1
                self.load_stats["remote"]["hits"] += 1
                self.load_stats["remote"]["bytes"] += nbytes
            self._tier_bw.observe("remote", "read", nbytes, fetch_seconds)
            if nbytes and os.path.exists(self.ledger_path):
                StorageLedger(self.ledger_path).adjust(float(nbytes))
        return True

    # -- load ------------------------------------------------------------------
    def load(self, sig: str,
             sharding_for_leaf: Callable[[int, tuple, np.dtype], Any] | None = None
             ) -> tuple[Any, float]:
        """Load entry ``sig``. Returns ``(value, seconds)``.

        ``sharding_for_leaf(i, shape, dtype)`` may return a
        ``jax.sharding.Sharding`` to place array leaf ``i`` directly onto the
        current mesh (possibly different from the one it was saved under);
        ``None`` leaves it as a host numpy array.

        With a remote tier, a local miss falls back to a read-through
        fetch (the entry is published locally, then loaded); the fetch
        wall-time is included in the returned seconds so realized
        per-node runtimes stay honest.

        With a memory tier, a resident signature short-circuits the
        whole path: the stored pytree is handed back zero-copy (no
        ``.npy`` read, no unpickle, no ``meta.json`` touch — the reuse
        bump stays tier-local), and every successful disk/remote load
        read-through promotes its value for the next caller.
        """
        if self._mem is not None:
            t0 = time.perf_counter()
            ent = self._mem.get(sig)
            if ent is not None:
                value = ent.value
                if sharding_for_leaf is not None:
                    value = self._place_leaves(value, sharding_for_leaf)
                seconds = time.perf_counter() - t0
                self._tier_bw.observe("memory", "read", ent.nbytes,
                                      seconds)
                with self._stats_lock:
                    self.load_stats["memory"]["hits"] += 1
                    self.load_stats["memory"]["bytes"] += ent.nbytes
                return value, seconds
            with self._stats_lock:
                self.load_stats["memory"]["misses"] += 1
        fetch_secs = 0.0
        for attempt in range(4):
            try:
                value, seconds, meta = self._load_once(sig,
                                                       sharding_for_leaf)
                self._note_load(sig)
                with self._stats_lock:
                    self.load_stats["local"]["hits"] += 1
                    self.load_stats["local"]["bytes"] += \
                        int(meta.get("nbytes", 0) or 0)
                if (self._mem is not None and not meta.get("chunked")
                        and not isinstance(value, Chunked)):
                    # Read-through promotion (chunk entries promote
                    # individually; manifests don't — their payload is
                    # not the value).
                    self._mem.put(
                        sig, value,
                        int(meta.get("nbytes", 0) or tree_nbytes(value)),
                        name=meta.get("name", ""), meta=meta,
                        state="durable")
                return value, seconds + fetch_secs
            except FileNotFoundError:
                # Either we raced an overwrite of the same signature (tmp
                # dir swapped in under us — retry against the fresh copy)
                # or the entry was never local (remote tier fallback).
                if self.remote is not None and not self.has_local(sig):
                    with self._stats_lock:
                        self.load_stats["local"]["misses"] += 1
                    t0 = time.perf_counter()
                    fetched = self._fetch_remote(sig)
                    fetch_secs += time.perf_counter() - t0
                    if fetched:
                        continue
                if attempt == 3 or not self.has(sig):
                    raise
        raise AssertionError("unreachable")

    @staticmethod
    def _place_leaves(value: Any, sharding_for_leaf) -> Any:
        """Re-place a memory-resident pytree's array leaves onto the
        caller's mesh. Leaf numbering matches the saved manifest (both
        are the pytree flatten order), so ``sharding_for_leaf`` sees the
        same indices it would on a disk load; non-array leaves and
        leaves the callback declines (None) pass through untouched."""
        leaves, treedef = jax.tree_util.tree_flatten(value)
        placed = []
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, (np.ndarray, jax.Array)):
                sharding = sharding_for_leaf(
                    i, tuple(leaf.shape), np.dtype(leaf.dtype))
                if sharding is not None:
                    leaf = jax.device_put(leaf, sharding)
            placed.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, placed)

    def _load_once(self, sig: str, sharding_for_leaf
                   ) -> tuple[Any, float, dict]:
        t0 = time.perf_counter()
        d = self._dir(sig)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        ch = meta.get("chunked")
        if ch and ch.get("combine") == "concat":
            # Partitioned materialization: reassemble from the per-chunk
            # entries (each load updates bandwidth/reuse stats itself; no
            # manifest-level bandwidth sample — its payload is empty).
            # Reduce manifests fall through: their payload *is* the
            # combined value.
            chunks = []
            for cs in ch["chunk_sigs"]:
                v, _ = self.load(cs)
                chunks.append(v)
            value = Chunked(chunks, ch["chunk_sigs"], "concat")
            return value, time.perf_counter() - t0, meta
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)

        def load_leaf(i_ent):
            i, ent = i_ent
            path = os.path.join(d, ent["file"])
            if ent["kind"] == "array":
                with self._stats_lock:
                    self.npy_leaf_reads += 1
                shape = tuple(ent["shape"])
                try:
                    dtype = np.dtype(ent["dtype"])
                except TypeError:
                    import ml_dtypes
                    dtype = np.dtype(getattr(ml_dtypes, ent["dtype"]))
                sharding = (sharding_for_leaf(i, shape, dtype)
                            if sharding_for_leaf else None)
                if sharding is not None:
                    mm = np.load(path, mmap_mode="r").view(dtype)
                    return jax.make_array_from_callback(
                        shape, sharding,
                        lambda idx, _mm=mm: np.ascontiguousarray(_mm[idx]))
                return np.load(path).view(dtype)
            with open(path, "rb") as f:
                return pickle.load(f)

        items = list(enumerate(meta["manifest"]))
        n_big_arrays = sum(
            1 for _, ent in items if ent["kind"] == "array"
            and int(np.prod(ent["shape"] or [1])) >= _PARALLEL_LEAF_MIN_BYTES // 8)
        if sharding_for_leaf is None and n_big_arrays >= 2:
            leaves = list(_leaf_io_pool().map(load_leaf, items))
        else:
            leaves = [load_leaf(it) for it in items]
        value = jax.tree_util.tree_unflatten(treedef, leaves)
        seconds = time.perf_counter() - t0
        self._update_bw("read", meta["nbytes"], seconds)
        return value, seconds, meta

    def _note_load(self, sig: str) -> None:
        """Record one observed load of ``sig`` (count + recency) in its
        ``meta.json`` — the per-entry reuse signal fleet eviction ranks
        against. Runs under the per-signature entry lock (same order as
        save/delete: entry lock, then index lock) and is best-effort: a
        concurrent delete simply wins.

        The *global* index is only re-synced when the count crosses a
        power of two: a per-load index RMW would serialize every load of
        every session on one flock'd file — exactly the load-heavy reuse
        path the store optimizes. O(log loads) index writes keep the
        evictor's ranking fresh where it matters (the 0→1 transition is
        the big protection signal; recency staleness only tie-breaks),
        and rebuild_index heals the index from meta.json after crashes.

        The entry lock is taken *non-blocking*: concurrent loaders of
        one hot entry (K variants pulling the same shared prefix) must
        never queue on a bookkeeping write — a contended bump is simply
        dropped, slightly undercounting a signal that is already hot."""
        now = time.time()
        lock = self._entry_lock(sig)
        if not lock.acquire(blocking=False):
            return  # someone else is recording/publishing — skip the bump
        try:
            mp = os.path.join(self._dir(sig), "meta.json")
            try:
                with open(mp) as f:
                    meta = json.load(f)
            except (FileNotFoundError, NotADirectoryError,
                    json.JSONDecodeError):
                return  # deleted (or overwrite-in-flight) under us
            loads = int(meta.get("loads", 0)) + 1
            meta["loads"] = loads
            meta["last_load"] = now
            if not self._rewrite_json(mp, meta):
                return
            if loads & (loads - 1) == 0:    # 1, 2, 4, 8, …
                self._index_apply(add={sig: self._index_entry(meta)})
        finally:
            lock.release()

    # -- compute / read leases (in-flight dedupe) --------------------------------
    def acquire_compute(self, sig: str) -> ComputeLease | None:
        """Try to take the fleet-wide compute lease for ``sig``.

        Returns a :class:`ComputeLease` when this caller should compute the
        value, or ``None`` when another session currently holds the lease
        (→ ``wait_compute`` and then load-or-retry). With a remote tier
        the lease is two-scope: local ``flock`` first (host-internal
        dedupe), then the remote TTL lease object (cross-host dedupe).
        A degraded remote tier is skipped — the host proceeds local-only,
        risking at worst one duplicate compute per signature fleet-wide."""
        lock = FileLock(self._lease_path(sig))
        if not lock.acquire(blocking=False):
            return None
        remote_lease = None
        if self.remote is not None and self.remote.available():
            remote_lease = self.remote.acquire_compute(sig)
            if remote_lease is None and self.remote.available():
                # A live holder on another host — not a degradation.
                lock.release()
                return None
        return ComputeLease(self, sig, lock, remote_lease=remote_lease)

    def wait_compute(self, sig: str, timeout: float | None = None,
                     cancel: "threading.Event | None" = None) -> bool:
        """Block until the current compute lease on ``sig`` is released.

        Registers a waiter marker first, so the lease holder knows the
        result is wanted fleet-wide and force-persists it before releasing.
        Returns False on timeout (the caller should fall back to computing
        the value itself — bounded waits keep the fleet deadlock-free even
        under pathological cross-session lease chains). ``cancel`` (a
        ``threading.Event``) aborts the wait early with False — the
        executor passes its job cancel flag so a cancelled session never
        sits out a long lease wait.

        With a remote tier, the holder may be on another host: the local
        ``flock`` is then uncontended and the wait continues by polling
        the remote TTL lease (with a remote waiter marker registered so
        the holder publishes before releasing)."""
        deadline = None if timeout is None \
            else time.monotonic() + float(timeout)
        marker = os.path.join(self._fleet_dir("leases"),
                              f"{sig}.w-{uuid.uuid4().hex}")
        remote_waiter = None
        try:
            with open(marker, "w") as f:
                f.write(str(os.getpid()))
            if self.remote is not None and self.remote.available():
                # Mirror the local protocol: register BEFORE waiting, so
                # a cross-host holder sees this waiter at its
                # post-compute persist decision (registering only once
                # the remote poll starts would lose the race against
                # fast nodes).
                remote_waiter = self.remote.register_waiter(sig)
            waiter = FileLock(self._lease_path(sig), shared=True)
            if not waiter.acquire(timeout=timeout, cancel=cancel):
                return False
            waiter.release()
            if cancel is not None and cancel.is_set():
                return False
            if self.remote is None:
                return True
            return self._wait_remote(sig, deadline, cancel=cancel)
        finally:
            if remote_waiter is not None:
                remote_waiter.release()
            try:
                os.unlink(marker)
            except OSError:
                pass

    def _wait_remote(self, sig: str, deadline: float | None,
                     cancel: "threading.Event | None" = None) -> bool:
        """Poll a cross-host compute lease until it releases/expires, the
        entry appears, or the deadline passes (False) — or ``cancel``
        fires (False). The caller (``wait_compute``) holds a remote TTL
        waiter marker for the duration, so the remote holder knows to
        force-persist. Probes bypass the marker cache — a stale negative
        here would send the caller straight into a duplicate compute."""
        remote = self.remote
        if remote is None or not remote.available():
            return True   # degraded: behave local-only
        interval = 0.05
        while True:
            if cancel is not None and cancel.is_set():
                return False
            if self.has_local(sig):
                return True
            # Fresh marker probe BEFORE the lease probe: a holder
            # commits then releases, so observing "no lease" with a
            # stale cached negative marker would send the caller into a
            # recompute of a committed entry. Probing the marker first
            # (and thereby refreshing the cache) closes that window.
            if remote.marker_meta(sig, fresh=True) is not None:
                return True
            if not remote.lease_live(sig):
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            sleep = interval
            if deadline is not None:
                sleep = min(sleep,
                            max(deadline - time.monotonic(), 0.01))
            time.sleep(sleep)
            interval = min(interval * 1.6, 1.0)

    @staticmethod
    def _waiter_is_dead(path: str) -> bool:
        """A waiter marker is stale iff its recorded pid is provably dead
        (same host) or the marker outlived any plausible lease wait."""
        try:
            pid = int(open(path).read().strip() or 0)
        except (OSError, ValueError):
            pid = 0
        if pid > 0:
            try:
                os.kill(pid, 0)
            except ProcessLookupError:
                return True
            except PermissionError:
                pass  # alive, different user
        try:
            return (time.time() - os.stat(path).st_mtime
                    > Store._TMP_ORPHAN_SECONDS)
        except OSError:
            return False  # already unlinked by its owner

    def _count_waiters(self, sig: str) -> int:
        prefix = f"{sig}.w-"
        n = 0
        try:
            names = os.listdir(self._fleet_dir("leases"))
        except FileNotFoundError:
            return 0
        for name in names:
            if not name.startswith(prefix):
                continue
            path = os.path.join(self._fleet_dir("leases"), name)
            if self._waiter_is_dead(path):
                # Crashed waiter (SIGKILL before its finally-unlink):
                # reap so it cannot force-persist values forever.
                try:
                    os.unlink(path)
                except OSError:
                    pass
                continue
            n += 1
        if self.remote is not None:
            n += self.remote.count_waiters(sig)
        return n

    def any_live_lease(self) -> bool:
        """Is any signature's lease (compute or read pin) currently held?
        Used as a guard before fleet-wide maintenance like a ledger
        reconcile — a held lease means another session is mid-run."""
        try:
            names = os.listdir(self._fleet_dir("leases"))
        except FileNotFoundError:
            return False
        for name in names:
            if not name.endswith(".lease"):
                continue
            if FileLock(os.path.join(self._fleet_dir("leases"), name)
                        ).locked_elsewhere():
                return True
        return False

    def acquire_read(self, sig: str) -> ReadPin | FileLock | None:
        """Pin ``sig`` against eviction (shared lease; see ``delete``).
        Non-blocking: returns None when the signature is being computed
        right now (then there is nothing on disk to pin yet anyway).

        When the entry exists only in the remote tier (a planned LOAD
        that will fetch), the pin extends to a remote TTL pin so no
        other host's remote eviction can delete the entry between this
        host's plan and its load."""
        lock = FileLock(self._lease_path(sig), shared=True)
        if not lock.acquire(blocking=False):
            return None
        if self.remote is None:
            return lock
        remote_pin = None
        if not self.has_local(sig) and self.remote.exists(sig):
            remote_pin = self.remote.acquire_pin(sig)
        return ReadPin(lock, remote_pin)

    # -- metadata / management ---------------------------------------------------
    def meta(self, sig: str) -> dict:
        """Entry metadata: local ``meta.json``, else the memory tier's
        resident record (write-back entries have no disk copy yet), else
        the remote commit marker (which carries name/nbytes/benefit
        stats — enough for the planner's load-cost estimate on a
        not-yet-fetched entry)."""
        try:
            with open(os.path.join(self._dir(sig), "meta.json")) as f:
                return json.load(f)
        except (FileNotFoundError, NotADirectoryError):
            if self._mem is not None:
                ent = self._mem.peek(sig)
                if ent is not None:
                    return dict(ent.meta)
            if self.remote is not None:
                marker = self.remote.marker_meta(sig)
                if marker is not None:
                    return marker
            raise

    def delete(self, sig: str, respect_leases: bool = True,
               keep_chunks: "frozenset | set | tuple" = ()) -> int:
        """Remove an entry; returns bytes freed (0 if absent or leased).

        With ``respect_leases`` (default), entries another session is
        actively computing or has pinned for a planned LOAD are left alone
        — fleet eviction must not yank values out from under a live
        session. The exclusive lease is *held* for the duration of the
        removal (not probed and dropped), so a read pin can never slip in
        between the check and the delete.

        Deleting a chunked *manifest* cascades to its chunk entries —
        except chunks another manifest still references, and chunks in
        ``keep_chunks`` (the §6.6 purge passes the chunk signatures the
        upcoming delta will splice from, so a stale manifest's removal
        never strands its still-valid sibling chunks). The returned byte
        count includes the cascade, so ledger credits stay equal to the
        bytes that actually left the disk."""
        lease_guard = None
        if respect_leases:
            lease_guard = FileLock(self._lease_path(sig))
            if not lease_guard.acquire(blocking=False):
                return 0
        chunk_sigs: list | None = None
        try:
            with self._entry_lock(sig):
                d = self._dir(sig)
                if not os.path.exists(d):
                    # Deletion is tier-wide: a memory-only resident copy
                    # (write-back, or a promotion outliving a sibling's
                    # disk delete) goes too, so tiers never disagree.
                    # Its bytes live in the memory tier's own ledger —
                    # nothing to credit to the disk ledger.
                    if self._mem is not None:
                        self._mem.drop(sig)
                    return 0
                try:
                    with open(os.path.join(d, "meta.json")) as f:
                        meta = json.load(f)
                    nbytes = meta.get("nbytes", 0)
                    chunk_sigs = meta.get("chunked", {}).get("chunk_sigs")
                except (FileNotFoundError, json.JSONDecodeError):
                    nbytes = 0
                self._retire_dir(d)
                self._index_apply(remove=[sig])
            if self._mem is not None:
                self._mem.drop(sig)
        finally:
            if lease_guard is not None:
                lease_guard.release()
        if chunk_sigs:
            nbytes += self._reap_unreferenced_chunks(
                chunk_sigs, keep_chunks, respect_leases)
        return nbytes

    def _reap_unreferenced_chunks(self, chunk_sigs, keep_chunks,
                                  respect_leases: bool) -> int:
        """Delete the given chunk entries unless some surviving manifest
        still references them (sibling variants share prefix chunks) or
        the caller asked to keep them. Two concurrent manifest deletes
        can each see the other's manifest alive and both skip a chunk —
        that orphan is :meth:`gc_orphan_chunks`'s job, never a lost
        value."""
        referenced: set = set()
        for ent in self.entries().values():
            referenced.update(ent.get("chunk_sigs", ()))
        freed = 0
        for cs in dict.fromkeys(chunk_sigs):
            if cs in keep_chunks or cs in referenced:
                continue
            freed += self.delete(cs, respect_leases=respect_leases)
        return freed

    def gc_orphan_chunks(self, min_age_seconds: float = 3600.0
                         ) -> tuple[int, int]:
        """Reclaim chunk entries no manifest references.

        Orphans come from a crash between chunk publish and manifest
        publish (the manifest is the splice's commit point) and from
        concurrent manifest deletes racing each other's reference scans.
        ``min_age_seconds`` protects in-flight splices — a live save may
        have published chunks whose manifest is milliseconds away.
        Returns ``(entries_reclaimed, bytes_reclaimed)``; callers credit
        the bytes to their ledger (the evictor's ``credit`` path)."""
        entries = self.entries()
        referenced = {cs for ent in entries.values()
                      for cs in ent.get("chunk_sigs", ())}
        now = time.time()
        n = freed = 0
        for sig, ent in entries.items():
            if not ent.get("is_chunk") or sig in referenced:
                continue
            if now - float(ent.get("created", now)) < min_age_seconds:
                continue
            nbytes = self.delete(sig)
            if nbytes > 0:
                n += 1
                freed += nbytes
        return n, freed

    # -- on-disk index ------------------------------------------------------------
    @staticmethod
    def _index_entry(meta: dict) -> dict:
        out = {"name": meta.get("name"), "nbytes": meta.get("nbytes", 0),
               "created": meta.get("created", 0.0)}
        # Benefit metadata for fleet eviction: cost-to-recompute C(n) and
        # the load-cost estimate recorded at save time (see eviction.py),
        # plus the observed load count / recency maintained by _note_load.
        # Mirrored here so ranking a whole store is one index read.
        for key in ("compute_s", "load_s_est", "loads", "last_load"):
            if key in meta:
                out[key] = meta[key]
        # Chunk bookkeeping, mirrored so manifest↔chunk reference scans
        # (delete cascade, gc_orphan_chunks, evictor sizing) are one
        # index read instead of N meta.json opens.
        if meta.get("is_chunk"):
            out["is_chunk"] = True
        ch = meta.get("chunked")
        if ch:
            out["chunk_sigs"] = list(ch.get("chunk_sigs", ()))
            out["chunk_bytes"] = ch.get("chunk_bytes", 0)
        return out

    def _index_apply(self, add: dict[str, dict] | None = None,
                     remove: list[str] | None = None) -> None:
        def txn(index):
            index.update(add or {})
            for sig in remove or ():
                index.pop(sig, None)
            return index

        update_json(self.index_path, txn, {})

    def rebuild_index(self) -> dict[str, dict]:
        """Reconcile the index with a directory scan (ground truth). Runs
        inside the index lock so concurrent publishes are not lost: they
        either precede the scan (and are seen) or follow the write (and
        re-add themselves)."""
        return update_json(
            self.index_path,
            lambda _cur: {sig: self._index_entry(m)
                          for sig, m in self._scan_entries().items()},
            {})

    def _scan_entries(self) -> dict[str, dict]:
        out = {}
        if not os.path.exists(self.root):
            return out
        for sub in sorted(os.listdir(self.root)):
            subdir = os.path.join(self.root, sub)
            if sub.startswith(".") or not os.path.isdir(subdir):
                continue
            for sig in sorted(os.listdir(subdir)):
                if ".tmp-" in sig:
                    continue  # in-progress staging dir, not an entry
                mp = os.path.join(subdir, sig, "meta.json")
                try:
                    with open(mp) as f:
                        out[sig] = json.load(f)
                except (FileNotFoundError, NotADirectoryError,
                        json.JSONDecodeError):
                    continue  # raced a concurrent delete / in-progress save
        return out

    def entries(self) -> dict[str, dict]:
        """Entry metadata by signature, served from the on-disk index
        (one atomic read; kept transactionally in sync by save/delete).
        A missing index (deleted out of band, or healing skipped) is
        rebuilt from the directory scan on demand."""
        index = read_json(self.index_path, None)
        if index is None:
            index = self.rebuild_index()
        return index

    def sigs_by_name(self) -> dict[str, list[str]]:
        by: dict[str, list[str]] = {}
        for sig, meta in self.entries().items():
            by.setdefault(meta["name"], []).append(sig)
        return by

    def total_bytes(self) -> int:
        """Local-tier on-disk bytes (the number the fleet ledger mirrors;
        the remote tier accounts its own — see ``tier_status``)."""
        return sum(m.get("nbytes", 0) for m in self.entries().values())

    def lease_counts(self) -> dict:
        """Live local-tier lease census: ``{"compute", "pins",
        "waiters"}``. Each ``.lease`` file's flock is probed (exclusive
        holder = a compute lease, shared holders = read pins); waiter
        markers are counted live-only. A snapshot for observability
        (``SessionServer.status()`` / docs/operations.md) — not a
        synchronization primitive."""
        out = {"compute": 0, "pins": 0, "waiters": 0}
        try:
            names = os.listdir(self._fleet_dir("leases"))
        except FileNotFoundError:
            return out
        for name in names:
            path = os.path.join(self._fleet_dir("leases"), name)
            if ".w-" in name:
                if not self._waiter_is_dead(path):
                    out["waiters"] += 1
                continue
            if not name.endswith(".lease"):
                continue
            state = FileLock(path).probe()
            if state == "exclusive":
                out["compute"] += 1
            elif state == "shared":
                out["pins"] += 1
        return out

    def tier_status(self) -> dict:
        """Per-tier observability snapshot, in TierStack order (memory →
        local → remote). Every attached tier reports one **unified
        record** — ``{name, bytes, budget, entries, leases, hits,
        misses}`` — plus tier-specific extras (memory: dirty/demotions/
        spills/offloads; local: ``remote_hits``; remote: ``available``
        and the transfer stats). ``budget`` is None where the store does
        not own one (the disk budget lives in the Materializer's
        ledger). Unattached tiers are None. The server's
        ``status()["tiers"]`` returns exactly this snapshot — one schema
        at both layers."""
        entries = self.entries()
        with self._stats_lock:
            stats = {tier: dict(d) for tier, d in self.load_stats.items()}
            remote_hits = self.remote_hits
        status: dict = {
            "memory": (self._mem.status()
                       if self._mem is not None else None),
            "local": {
                "name": "local",
                "bytes": sum(int(m.get("nbytes", 0) or 0)
                             for m in entries.values()),
                "budget": None,
                "entries": len(entries),
                "leases": self.lease_counts(),
                "hits": stats["local"]["hits"],
                "misses": stats["local"]["misses"],
                "remote_hits": remote_hits,
            },
            "remote": None,
        }
        if self.remote is not None:
            remote_entries = self.remote.entries()
            status["remote"] = {
                "name": "remote",
                "available": self.remote.available(),
                "bytes": sum(int(m.get("nbytes", 0) or 0)
                             for m in remote_entries.values()),
                "budget": None,
                "entries": len(remote_entries),
                "leases": self.remote.lease_counts(),
                "hits": stats["remote"]["hits"],
                "misses": stats["remote"]["misses"],
                **self.remote.stats.snapshot(),
            }
        return status

    # -- bandwidth model (feeds l_i estimates) ------------------------------------
    def _update_bw(self, key: str, nbytes: int, seconds: float) -> None:
        # Merge-on-flush: the observation is EWMA-blended into the shared
        # on-disk estimate under its lock, so concurrent sessions (and the
        # pipelined executor's worker threads) refine one number.
        if seconds <= 0 or nbytes <= 0:
            return
        self._bw.update(key, nbytes / seconds)

    def est_load_seconds(self, nbytes: float, sig: str | None = None
                         ) -> float:
        """Estimated seconds to load ``nbytes`` — the paper's ``l_i``,
        priced per tier: with a ``sig``, the cheapest tier that can
        serve it (memory → local → remote, each with its own measured
        EWMA bandwidth and latency floor). Without one (or for an entry
        resident nowhere) the local disk tier is priced — the durable
        default every *write* decision reasons about, and numerically
        identical to the historical single-number estimate."""
        tier = "local"
        if sig is not None:
            if self._mem is not None and self._mem.has(sig):
                tier = "memory"
            elif self.has_local(sig):
                tier = "local"
            elif self.remote is not None and self.remote.exists(sig):
                tier = "remote"
        return self._tier_bw.est_load_seconds(tier, nbytes)
