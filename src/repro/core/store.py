"""Content-addressed materialization store (paper's "materialization
operator" + Helix-JAX's distributed checkpoint substrate).

Entries are keyed by the node's *signature* (see signature.py), so a lookup
hit is exactly the paper's "equivalent materialization" (Def. 3). Values are
arbitrary pytrees whose array leaves may be sharded ``jax.Array``s.

Array leaves are persisted as ``.npy`` and reloaded with
``jax.make_array_from_callback`` against a **target sharding**, reading only
the slices each device needs (``np.load(mmap_mode='r')``). That means a value
materialized under mesh A can be restored under mesh B — the elastic-restart
path. Non-array leaves are pickled.

The store records measured save/load wall-times and byte sizes per entry;
these feed the cost model's ``l_i`` estimates (paper §5.1: l_i =
bytes / store bandwidth).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Callable

import numpy as np

import jax


@dataclasses.dataclass
class SaveInfo:
    nbytes: int
    seconds: float


def _leaf_to_host(leaf: Any) -> Any:
    if isinstance(leaf, jax.Array):
        return np.asarray(jax.device_get(leaf))
    return leaf


def tree_nbytes(value: Any) -> int:
    """Pre-save storage estimate for a pytree (used by OMP's budget)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, (jax.Array, np.ndarray)):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        else:
            total += 64  # nominal
    return total


class Store:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        # measured aggregate write bandwidth (bytes/s), EWMA
        self._bw_write: float | None = None
        self._bw_read: float | None = None

    # -- paths ---------------------------------------------------------------
    def _dir(self, sig: str) -> str:
        return os.path.join(self.root, sig[:2], sig)

    def has(self, sig: str) -> bool:
        return os.path.exists(os.path.join(self._dir(sig), "meta.json"))

    # -- save ------------------------------------------------------------------
    def save(self, sig: str, name: str, value: Any,
             extra_meta: dict | None = None) -> SaveInfo:
        t0 = time.perf_counter()
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        d = self._dir(sig)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = jax.tree_util.tree_flatten(host_value)
        manifest = []
        nbytes = 0
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, np.ndarray):
                fn = f"leaf_{i}.npy"
                logical = str(leaf.dtype)
                to_save = leaf
                if leaf.dtype.kind not in "biufc":  # ml_dtypes (bf16, fp8…)
                    to_save = leaf.view(
                        {1: np.uint8, 2: np.uint16, 4: np.uint32}[
                            leaf.dtype.itemsize])
                np.save(os.path.join(tmp, fn), to_save, allow_pickle=False)
                manifest.append({"kind": "array", "file": fn,
                                 "shape": list(leaf.shape),
                                 "dtype": logical})
                nbytes += leaf.nbytes
            else:
                fn = f"leaf_{i}.pkl"
                with open(os.path.join(tmp, fn), "wb") as f:
                    pickle.dump(leaf, f)
                manifest.append({"kind": "pickle", "file": fn})
                nbytes += os.path.getsize(os.path.join(tmp, fn))
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        seconds = time.perf_counter() - t0
        meta = {
            "name": name, "sig": sig, "nbytes": nbytes,
            "save_seconds": seconds, "created": time.time(),
            "manifest": manifest,
        }
        meta.update(extra_meta or {})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with self._lock:
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._update_bw("_bw_write", nbytes, seconds)
        return SaveInfo(nbytes=nbytes, seconds=seconds)

    def save_async(self, sig: str, name: str, value: Any,
                   extra_meta: dict | None = None) -> threading.Thread:
        """Overlapped materialization: snapshot to host synchronously (the
        cheap part), write to disk on a worker thread. The paper materializes
        synchronously; this removes the write from the critical path."""
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        th = threading.Thread(
            target=self.save, args=(sig, name, host_value),
            kwargs={"extra_meta": extra_meta}, daemon=True)
        th.start()
        return th

    # -- load ------------------------------------------------------------------
    def load(self, sig: str,
             sharding_for_leaf: Callable[[int, tuple, np.dtype], Any] | None = None
             ) -> tuple[Any, float]:
        """Load entry ``sig``. Returns ``(value, seconds)``.

        ``sharding_for_leaf(i, shape, dtype)`` may return a
        ``jax.sharding.Sharding`` to place array leaf ``i`` directly onto the
        current mesh (possibly different from the one it was saved under);
        ``None`` leaves it as a host numpy array.
        """
        t0 = time.perf_counter()
        d = self._dir(sig)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for i, ent in enumerate(meta["manifest"]):
            path = os.path.join(d, ent["file"])
            if ent["kind"] == "array":
                shape = tuple(ent["shape"])
                try:
                    dtype = np.dtype(ent["dtype"])
                except TypeError:
                    import ml_dtypes
                    dtype = np.dtype(getattr(ml_dtypes, ent["dtype"]))
                sharding = (sharding_for_leaf(i, shape, dtype)
                            if sharding_for_leaf else None)
                if sharding is not None:
                    mm = np.load(path, mmap_mode="r").view(dtype)
                    arr = jax.make_array_from_callback(
                        shape, sharding,
                        lambda idx, _mm=mm: np.ascontiguousarray(_mm[idx]))
                    leaves.append(arr)
                else:
                    leaves.append(np.load(path).view(dtype))
            else:
                with open(path, "rb") as f:
                    leaves.append(pickle.load(f))
        value = jax.tree_util.tree_unflatten(treedef, leaves)
        seconds = time.perf_counter() - t0
        with self._lock:
            self._update_bw("_bw_read", meta["nbytes"], seconds)
        return value, seconds

    # -- metadata / management ---------------------------------------------------
    def meta(self, sig: str) -> dict:
        with open(os.path.join(self._dir(sig), "meta.json")) as f:
            return json.load(f)

    def delete(self, sig: str) -> int:
        d = self._dir(sig)
        if not os.path.exists(d):
            return 0
        nbytes = self.meta(sig).get("nbytes", 0)
        shutil.rmtree(d)
        return nbytes

    def entries(self) -> dict[str, dict]:
        out = {}
        if not os.path.exists(self.root):
            return out
        for sub in os.listdir(self.root):
            subdir = os.path.join(self.root, sub)
            if not os.path.isdir(subdir):
                continue
            for sig in os.listdir(subdir):
                mp = os.path.join(subdir, sig, "meta.json")
                if os.path.exists(mp):
                    with open(mp) as f:
                        out[sig] = json.load(f)
        return out

    def sigs_by_name(self) -> dict[str, list[str]]:
        by: dict[str, list[str]] = {}
        for sig, meta in self.entries().items():
            by.setdefault(meta["name"], []).append(sig)
        return by

    def total_bytes(self) -> int:
        return sum(m.get("nbytes", 0) for m in self.entries().values())

    # -- bandwidth model (feeds l_i estimates) ------------------------------------
    def _update_bw(self, attr: str, nbytes: int, seconds: float) -> None:
        if seconds <= 0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        cur = getattr(self, attr)
        setattr(self, attr, bw if cur is None else 0.7 * cur + 0.3 * bw)

    def est_load_seconds(self, nbytes: float) -> float:
        bw = self._bw_read or self._bw_write or 500e6  # default 500 MB/s
        return nbytes / bw + 1e-4
