"""Content-addressed materialization store (paper's "materialization
operator" + Helix-JAX's distributed checkpoint substrate).

Entries are keyed by the node's *signature* (see signature.py), so a lookup
hit is exactly the paper's "equivalent materialization" (Def. 3). Values are
arbitrary pytrees whose array leaves may be sharded ``jax.Array``s.

Array leaves are persisted as ``.npy`` and reloaded with
``jax.make_array_from_callback`` against a **target sharding**, reading only
the slices each device needs (``np.load(mmap_mode='r')``). That means a value
materialized under mesh A can be restored under mesh B — the elastic-restart
path. Non-array leaves are pickled.

The store is safe for concurrent use by the pipelined executor:

* ``save_enqueue`` hands a host snapshot to a dedicated **writer thread**
  (replacing the old thread-per-save ``save_async``); in-flight bytes are
  bounded by ``max_inflight_bytes`` so a burst of materializations cannot
  exhaust host memory. Each :class:`PendingSave` reports the measured write
  time, which the executor folds into ``mat_seconds``.
* Multi-leaf values are written/read with **per-leaf parallel .npy I/O**
  (shared small thread pool) — large pytrees saturate disk bandwidth
  instead of serializing leaf by leaf.
* Saves build a uniquely-named temp dir and publish it with an atomic
  rename under the store lock, so concurrent saves of the same signature
  are last-writer-wins and readers never observe partial entries; loads
  retry once if they race an overwrite.

The store records measured save/load wall-times and byte sizes per entry;
these feed the cost model's ``l_i`` estimates (paper §5.1: l_i =
bytes / store bandwidth) via a thread-safe bandwidth EWMA.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import pickle
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

import jax


@dataclasses.dataclass
class SaveInfo:
    nbytes: int
    seconds: float


class PendingSave:
    """Handle for a queued write. ``result()`` blocks until the writer has
    persisted the entry and returns its :class:`SaveInfo`; ``join()`` is
    kept for drop-in compatibility with the old thread-based API."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._info: SaveInfo | None = None
        self._error: BaseException | None = None

    def _finish(self, info: SaveInfo | None,
                error: BaseException | None = None) -> None:
        self._info = info
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> SaveInfo:
        if not self._event.wait(timeout):
            raise TimeoutError("materialization write still pending")
        if self._error is not None:
            raise self._error
        assert self._info is not None
        return self._info

    def join(self, timeout: float | None = None) -> None:
        self._event.wait(timeout)


def _leaf_to_host(leaf: Any) -> Any:
    if isinstance(leaf, jax.Array):
        return np.asarray(jax.device_get(leaf))
    return leaf


def tree_nbytes(value: Any) -> int:
    """Pre-save storage estimate for a pytree (used by OMP's budget)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, (jax.Array, np.ndarray)):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        else:
            total += 64  # nominal
    return total


# Leaves smaller than this are not worth a pool round-trip.
_PARALLEL_LEAF_MIN_BYTES = 1 << 20

_io_pool: ThreadPoolExecutor | None = None
_io_pool_lock = threading.Lock()


def _leaf_io_pool() -> ThreadPoolExecutor:
    """Small process-wide pool for per-leaf .npy reads/writes."""
    global _io_pool
    with _io_pool_lock:
        if _io_pool is None:
            _io_pool = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="store-leaf-io")
        return _io_pool


def _npy_storage_view(leaf: np.ndarray) -> np.ndarray:
    """Reinterpret ml_dtypes leaves (bf16, fp8…) as plain uints for .npy."""
    if leaf.dtype.kind in "biufc":
        return leaf
    return leaf.view({1: np.uint8, 2: np.uint16, 4: np.uint32}
                     [leaf.dtype.itemsize])


class Store:
    _tmp_counter = itertools.count()

    def __init__(self, root: str, max_inflight_bytes: int = 1 << 30):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._reap_stale_tmp()
        self._lock = threading.Lock()
        # measured aggregate write bandwidth (bytes/s), EWMA
        self._bw_write: float | None = None
        self._bw_read: float | None = None
        # dedicated writer queue (overlapped materialization)
        self.max_inflight_bytes = int(max_inflight_bytes)
        self._writer_cv = threading.Condition()
        self._writer_queue: deque = deque()
        self._writer_thread: threading.Thread | None = None
        self._inflight_bytes = 0

    def _reap_stale_tmp(self) -> None:
        """Remove staging dirs orphaned by a crash mid-save. They contain a
        meta.json, so without this sweep entries()/total_bytes() would count
        them as phantom entries forever."""
        for sub in os.listdir(self.root):
            subdir = os.path.join(self.root, sub)
            if not os.path.isdir(subdir):
                continue
            for name in os.listdir(subdir):
                if ".tmp-" in name:
                    shutil.rmtree(os.path.join(subdir, name),
                                  ignore_errors=True)

    # -- paths ---------------------------------------------------------------
    def _dir(self, sig: str) -> str:
        return os.path.join(self.root, sig[:2], sig)

    def has(self, sig: str) -> bool:
        return os.path.exists(os.path.join(self._dir(sig), "meta.json"))

    # -- save ------------------------------------------------------------------
    def save(self, sig: str, name: str, value: Any,
             extra_meta: dict | None = None) -> SaveInfo:
        t0 = time.perf_counter()
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        d = self._dir(sig)
        # Unique temp dir: concurrent saves of one signature must not
        # clobber each other's staging area (last rename wins below).
        tmp = (f"{d}.tmp-{os.getpid()}-{threading.get_ident()}"
               f"-{next(self._tmp_counter)}")
        os.makedirs(tmp, exist_ok=True)
        try:
            manifest, nbytes = self._write_leaves(tmp, host_value)
            seconds = time.perf_counter() - t0
            meta = {
                "name": name, "sig": sig, "nbytes": nbytes,
                "save_seconds": seconds, "created": time.time(),
                "manifest": manifest,
            }
            meta.update(extra_meta or {})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with self._lock:
                if os.path.exists(d):
                    shutil.rmtree(d)
                os.rename(tmp, d)
                self._update_bw("_bw_write", nbytes, seconds)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        return SaveInfo(nbytes=nbytes, seconds=seconds)

    def _write_leaves(self, tmp: str, host_value: Any) -> tuple[list, int]:
        leaves, treedef = jax.tree_util.tree_flatten(host_value)
        manifest: list[dict] = []
        nbytes = 0
        array_jobs: list[tuple[str, np.ndarray]] = []
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, np.ndarray):
                fn = f"leaf_{i}.npy"
                manifest.append({"kind": "array", "file": fn,
                                 "shape": list(leaf.shape),
                                 "dtype": str(leaf.dtype)})
                nbytes += leaf.nbytes
                array_jobs.append((os.path.join(tmp, fn), leaf))
            else:
                fn = f"leaf_{i}.pkl"
                with open(os.path.join(tmp, fn), "wb") as f:
                    pickle.dump(leaf, f)
                manifest.append({"kind": "pickle", "file": fn})
                nbytes += os.path.getsize(os.path.join(tmp, fn))

        def write_one(job):
            path, leaf = job
            np.save(path, _npy_storage_view(leaf), allow_pickle=False)

        big = [j for j in array_jobs
               if j[1].nbytes >= _PARALLEL_LEAF_MIN_BYTES]
        if len(big) >= 2:
            list(_leaf_io_pool().map(write_one, array_jobs))
        else:
            for job in array_jobs:
                write_one(job)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        return manifest, nbytes

    # -- writer queue ------------------------------------------------------------
    def save_enqueue(self, sig: str, name: str, value: Any,
                     extra_meta: dict | None = None) -> PendingSave:
        """Queue a write on the store's dedicated writer thread.

        The device→host snapshot happens synchronously (cheap, and it frees
        the caller to evict the value); the disk write runs off the critical
        path. Blocks while the writer's in-flight bytes exceed
        ``max_inflight_bytes`` so queued materializations cannot exhaust
        host memory.
        """
        host_value = jax.tree_util.tree_map(_leaf_to_host, value)
        est = tree_nbytes(host_value)
        pending = PendingSave()
        with self._writer_cv:
            while (self._inflight_bytes > 0
                   and self._inflight_bytes + est > self.max_inflight_bytes):
                self._writer_cv.wait()
            self._inflight_bytes += est
            self._writer_queue.append(
                (sig, name, host_value, extra_meta, est, pending))
            if self._writer_thread is None or not self._writer_thread.is_alive():
                self._writer_thread = threading.Thread(
                    target=self._writer_loop, name="store-writer", daemon=True)
                self._writer_thread.start()
            self._writer_cv.notify_all()
        return pending

    def _writer_loop(self) -> None:
        while True:
            with self._writer_cv:
                if not self._writer_queue:
                    # Exit when idle; save_enqueue restarts the thread on
                    # demand, so an idle Store pins no thread for life.
                    self._writer_thread = None
                    return
                sig, name, host_value, extra_meta, est, pending = \
                    self._writer_queue.popleft()
            try:
                info = self.save(sig, name, host_value,
                                 extra_meta=extra_meta)
                pending._finish(info)
            except BaseException as e:
                pending._finish(None, e)
            with self._writer_cv:
                self._inflight_bytes -= est
                self._writer_cv.notify_all()

    def save_async(self, sig: str, name: str, value: Any,
                   extra_meta: dict | None = None) -> PendingSave:
        """Deprecated alias for :meth:`save_enqueue` (kept for callers that
        still ``.join()`` the returned handle)."""
        return self.save_enqueue(sig, name, value, extra_meta=extra_meta)

    def writer_drain(self) -> None:
        """Block until every queued write has been persisted."""
        with self._writer_cv:
            while self._writer_queue or self._inflight_bytes > 0:
                self._writer_cv.wait()

    # -- load ------------------------------------------------------------------
    def load(self, sig: str,
             sharding_for_leaf: Callable[[int, tuple, np.dtype], Any] | None = None
             ) -> tuple[Any, float]:
        """Load entry ``sig``. Returns ``(value, seconds)``.

        ``sharding_for_leaf(i, shape, dtype)`` may return a
        ``jax.sharding.Sharding`` to place array leaf ``i`` directly onto the
        current mesh (possibly different from the one it was saved under);
        ``None`` leaves it as a host numpy array.
        """
        for attempt in range(3):
            try:
                return self._load_once(sig, sharding_for_leaf)
            except FileNotFoundError:
                # Raced an overwrite of the same signature (tmp dir swapped
                # in under us). If the entry still exists, retry against the
                # fresh copy; otherwise it is genuinely gone.
                if attempt == 2 or not self.has(sig):
                    raise
        raise AssertionError("unreachable")

    def _load_once(self, sig: str, sharding_for_leaf) -> tuple[Any, float]:
        t0 = time.perf_counter()
        d = self._dir(sig)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)

        def load_leaf(i_ent):
            i, ent = i_ent
            path = os.path.join(d, ent["file"])
            if ent["kind"] == "array":
                shape = tuple(ent["shape"])
                try:
                    dtype = np.dtype(ent["dtype"])
                except TypeError:
                    import ml_dtypes
                    dtype = np.dtype(getattr(ml_dtypes, ent["dtype"]))
                sharding = (sharding_for_leaf(i, shape, dtype)
                            if sharding_for_leaf else None)
                if sharding is not None:
                    mm = np.load(path, mmap_mode="r").view(dtype)
                    return jax.make_array_from_callback(
                        shape, sharding,
                        lambda idx, _mm=mm: np.ascontiguousarray(_mm[idx]))
                return np.load(path).view(dtype)
            with open(path, "rb") as f:
                return pickle.load(f)

        items = list(enumerate(meta["manifest"]))
        n_big_arrays = sum(
            1 for _, ent in items if ent["kind"] == "array"
            and int(np.prod(ent["shape"] or [1])) >= _PARALLEL_LEAF_MIN_BYTES // 8)
        if sharding_for_leaf is None and n_big_arrays >= 2:
            leaves = list(_leaf_io_pool().map(load_leaf, items))
        else:
            leaves = [load_leaf(it) for it in items]
        value = jax.tree_util.tree_unflatten(treedef, leaves)
        seconds = time.perf_counter() - t0
        with self._lock:
            self._update_bw("_bw_read", meta["nbytes"], seconds)
        return value, seconds

    # -- metadata / management ---------------------------------------------------
    def meta(self, sig: str) -> dict:
        with open(os.path.join(self._dir(sig), "meta.json")) as f:
            return json.load(f)

    def delete(self, sig: str) -> int:
        with self._lock:
            d = self._dir(sig)
            if not os.path.exists(d):
                return 0
            try:
                with open(os.path.join(d, "meta.json")) as f:
                    nbytes = json.load(f).get("nbytes", 0)
            except (FileNotFoundError, json.JSONDecodeError):
                nbytes = 0
            shutil.rmtree(d, ignore_errors=True)
            return nbytes

    def entries(self) -> dict[str, dict]:
        out = {}
        if not os.path.exists(self.root):
            return out
        for sub in sorted(os.listdir(self.root)):
            subdir = os.path.join(self.root, sub)
            if not os.path.isdir(subdir):
                continue
            for sig in sorted(os.listdir(subdir)):
                if ".tmp-" in sig:
                    continue  # in-progress staging dir, not an entry
                mp = os.path.join(subdir, sig, "meta.json")
                try:
                    with open(mp) as f:
                        out[sig] = json.load(f)
                except (FileNotFoundError, NotADirectoryError):
                    continue  # raced a concurrent delete / in-progress save
        return out

    def sigs_by_name(self) -> dict[str, list[str]]:
        by: dict[str, list[str]] = {}
        for sig, meta in self.entries().items():
            by.setdefault(meta["name"], []).append(sig)
        return by

    def total_bytes(self) -> int:
        return sum(m.get("nbytes", 0) for m in self.entries().values())

    # -- bandwidth model (feeds l_i estimates) ------------------------------------
    def _update_bw(self, attr: str, nbytes: int, seconds: float) -> None:
        # Callers hold self._lock, keeping the EWMA race-free under the
        # pipelined executor's concurrent saves/loads.
        if seconds <= 0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        cur = getattr(self, attr)
        setattr(self, attr, bw if cur is None else 0.7 * cur + 0.3 * bw)

    def est_load_seconds(self, nbytes: float) -> float:
        with self._lock:
            bw = self._bw_read or self._bw_write or 500e6  # default 500 MB/s
        return nbytes / bw + 1e-4
