"""Cross-process coordination primitives for the shared materialization
store (fleet mode).

One workdir may now be driven by many sessions at once — concurrent threads
in a sweep, or independent OS processes sharing a filesystem. Everything
here builds on POSIX ``flock``:

* :class:`FileLock` — an advisory lock on a dedicated lock file. ``flock``
  is per *open file description*, so two locks on the same path conflict
  even inside one process (each ``FileLock`` opens its own fd), and the
  kernel releases the lock automatically when the holder dies — that is
  the stale-lease story: a crashed session can never wedge the fleet.
* :func:`update_json` — read-modify-write a small JSON file atomically
  (under its sibling ``.lock`` file, published with ``os.replace``).
* :class:`StorageLedger` — the fleet-shared used-bytes ledger backing the
  materialization budget: sessions reserve/release bytes against one
  on-disk counter instead of each keeping a private (and mutually
  clobbering) tally.
* :class:`SharedEwma` — merge-on-flush EWMA statistics (store bandwidth,
  feeding the cost model's l_i estimates): each observation is blended
  into the on-disk value under the lock, so N sessions refine one shared
  estimate rather than overwriting each other's.

On platforms without ``fcntl`` the locks degrade to process-local
``threading`` locks: single-process semantics stay correct, multi-process
sharing is unsupported there.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable

try:
    import fcntl
    HAVE_FLOCK = True
except ImportError:  # pragma: no cover - non-POSIX fallback
    fcntl = None
    HAVE_FLOCK = False

# Fallback registry: path -> (lock, reader/writer bookkeeping is collapsed
# to exclusive-only; good enough for the single-process degradation).
_local_locks: dict[str, threading.Lock] = {}
_local_registry_lock = threading.Lock()


def _local_lock_for(path: str) -> threading.Lock:
    with _local_registry_lock:
        if path not in _local_locks:
            _local_locks[path] = threading.Lock()
        return _local_locks[path]


class FileLock:
    """Advisory file lock (``flock``). Create one instance per acquisition
    site — instances must not be shared between threads.

    ``shared=True`` takes the lock in shared (reader) mode: any number of
    shared holders coexist, but they exclude an exclusive holder and vice
    versa. The non-flock fallback treats shared as exclusive.
    """

    def __init__(self, path: str, shared: bool = False):
        self.path = path
        self.shared = shared
        self._fd: int | None = None
        self._local: threading.Lock | None = None

    def acquire(self, blocking: bool = True,
                timeout: float | None = None,
                cancel: "threading.Event | None" = None) -> bool:
        """Take the lock. ``timeout`` bounds a blocking acquire;
        ``cancel`` (a ``threading.Event``) aborts one early — a set
        event makes this return False at the next poll step, so a
        cancelled job never sits in an unbounded lease wait. Passing
        ``cancel`` forces the polling path even with no timeout."""
        if not HAVE_FLOCK:
            self._local = _local_lock_for(self.path)
            if blocking and cancel is not None:
                deadline = None if timeout is None \
                    else time.monotonic() + timeout
                got = False
                while True:
                    if self._local.acquire(False):
                        got = True
                        break
                    if cancel.is_set() or (
                            deadline is not None
                            and time.monotonic() >= deadline):
                        break
                    time.sleep(0.005)
            else:
                got = self._local.acquire(
                    blocking, -1 if timeout is None else timeout) \
                    if blocking else self._local.acquire(False)
            if not got:
                self._local = None
            return got
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        mode = fcntl.LOCK_SH if self.shared else fcntl.LOCK_EX
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
            got = False
            try:
                if blocking and deadline is None and cancel is None:
                    fcntl.flock(fd, mode)
                    got = True
                else:
                    while True:
                        try:
                            fcntl.flock(fd, mode | fcntl.LOCK_NB)
                            got = True
                            break
                        except OSError:
                            if not blocking or (
                                    deadline is not None
                                    and time.monotonic() >= deadline):
                                break
                            if cancel is not None and cancel.is_set():
                                break
                            time.sleep(0.005)
                if not got:
                    os.close(fd)
                    return False
                # The store's metadata janitor may unlink a lock file it
                # proved idle; if that happened between our open and
                # flock, we hold a lock on a dead inode that a fresh
                # opener cannot see. Verify the path still names our
                # inode — retry with a fresh fd otherwise.
                try:
                    if os.fstat(fd).st_ino == os.stat(self.path).st_ino:
                        self._fd = fd
                        return True
                except OSError:
                    pass
                os.close(fd)
            except BaseException:
                os.close(fd)
                raise

    def release(self) -> None:
        if self._fd is not None:
            try:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            finally:
                os.close(self._fd)
                self._fd = None
        elif self._local is not None:
            self._local.release()
            self._local = None

    def locked_elsewhere(self) -> bool:
        """Probe: is someone (anyone, any mode) holding this lock? Leaves
        the lock unheld on return."""
        if self.acquire(blocking=False):
            self.release()
            return False
        return True

    def probe(self) -> str:
        """Who holds this lock right now: ``"free"``, ``"shared"``, or
        ``"exclusive"``. Two non-blocking probes (exclusive, then
        shared): an exclusive probe succeeds only on a free lock; a
        shared probe coexists with shared holders but not an exclusive
        one. Lets the store's lease census tell compute leases
        (exclusive) from read pins (shared) without bookkeeping files.
        Leaves the lock unheld on return; the answer is inherently a
        snapshot."""
        ex = FileLock(self.path)
        if ex.acquire(blocking=False):
            ex.release()
            return "free"
        sh = FileLock(self.path, shared=True)
        if sh.acquire(blocking=False):
            sh.release()
            return "shared"
        return "exclusive"

    def __enter__(self) -> "FileLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


def update_json(path: str, fn: Callable[[Any], Any], default: Any) -> Any:
    """Atomically read-modify-write the JSON blob at ``path``.

    ``fn`` receives the current value (or ``default`` when the file is
    missing/corrupt) and returns the value to persist; returning ``None``
    skips the write. Serialized fleet-wide under ``path + ".lock"``;
    published via temp file + ``os.replace`` so concurrent lock-free
    readers never see a torn file. Returns the persisted (or current)
    value.
    """
    with FileLock(path + ".lock"):
        current = read_json(path, default)
        out = fn(current)
        if out is None:
            return current
        tmp = f"{path}.tmp-{os.getpid()}-{threading.get_ident()}"
        with open(tmp, "w") as f:
            json.dump(out, f)
        os.replace(tmp, path)
        return out


def read_json(path: str, default: Any) -> Any:
    """Best-effort read of an atomically-published JSON file (no lock:
    ``os.replace`` publication means we only ever see a whole file)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return default


class StorageLedger:
    """Fleet-shared used-bytes accounting for the materialization budget.

    The single source of truth is ``{"used_bytes": float}`` on disk;
    reserve/release are read-modify-write transactions under the ledger
    lock, so concurrent sessions can never over-commit a shared budget the
    way independent in-memory tallies do.
    """

    def __init__(self, path: str):
        self.path = path

    def used(self) -> float:
        return float(read_json(self.path, {}).get("used_bytes", 0.0))

    def reset(self, used_bytes: float) -> None:
        update_json(self.path, lambda _:
                    {"used_bytes": float(max(0.0, used_bytes))}, {})

    def ensure(self, used_bytes: float) -> None:
        """Initialize the ledger iff it does not exist yet (first session
        to open a workdir seeds it from the store's current size)."""
        update_json(self.path, lambda blob:
                    None if "used_bytes" in blob
                    else {"used_bytes": float(max(0.0, used_bytes))}, {})

    def try_reserve(self, nbytes: float, budget: float) -> bool:
        """Reserve ``nbytes`` iff the total stays within ``budget``."""
        ok = [False]

        def txn(blob):
            used = float(blob.get("used_bytes", 0.0))
            if used + nbytes > budget:
                return None
            ok[0] = True
            return {"used_bytes": used + float(nbytes)}

        update_json(self.path, txn, {})
        return ok[0]

    def release(self, nbytes: float) -> None:
        """Credit ``nbytes`` back (freed by a delete/evict or an undone
        reservation). The fleet evictor routes every eviction's freed
        bytes through here so N concurrent sessions see one consistent
        budget."""
        self.adjust(-float(nbytes))

    def adjust(self, delta: float) -> None:
        """Unconditionally shift the used-bytes counter by ``delta``
        (clamped at 0) — the one RMW primitive credits and reconciles
        share. The top-up direction *reconciles* a reservation made from
        a pre-save estimate with the actual on-disk size once the write
        lands: the bytes are already on disk, so honesty beats refusal
        even when it momentarily overshoots the budget."""
        if delta == 0:
            return
        update_json(self.path, lambda blob: {
            "used_bytes": max(0.0, float(blob.get("used_bytes", 0.0))
                              + float(delta))}, {})


class SharedEwma:
    """Merge-on-flush EWMA statistics shared across sessions.

    Observations EWMA-accumulate in memory (cheap — this sits on the
    store's save/load hot path); at most once per ``flush_interval`` per
    key the running estimate is blended into the *on-disk* value under
    the file lock (new = (1-alpha)·disk + alpha·local) and the merged
    fleet view is adopted back. N sessions thus refine one shared
    estimate without a locked read-modify-write per observation. The
    first observation of a key flushes immediately so cold sessions
    publish an estimate early.
    """

    def __init__(self, path: str, alpha: float = 0.3,
                 flush_interval: float = 1.0):
        self.path = path
        self.alpha = alpha
        self.flush_interval = flush_interval
        self._lock = threading.Lock()
        self._local: dict[str, float] = {}
        self._last_flush: dict[str, float] = {}
        self._disk_cache: dict[str, float] | None = None

    def update(self, key: str, value: float) -> float:
        with self._lock:
            cur = self._local.get(key)
            local = (value if cur is None
                     else (1 - self.alpha) * cur + self.alpha * value)
            self._local[key] = local
            now = time.monotonic()
            last = self._last_flush.get(key)
            if last is not None and now - last < self.flush_interval:
                return local
            self._last_flush[key] = now

        def txn(blob):
            disk = blob.get(key)
            blob[key] = (local if disk is None
                         else (1 - self.alpha) * float(disk)
                         + self.alpha * local)
            return blob

        out = update_json(self.path, txn, {})
        with self._lock:
            self._disk_cache = {k: float(v) for k, v in out.items()}
            self._local[key] = self._disk_cache[key]
            return self._local[key]

    def get(self, key: str) -> float | None:
        with self._lock:
            if key in self._local:
                return self._local[key]
            if self._disk_cache is None:
                self._disk_cache = {k: float(v) for k, v in
                                    read_json(self.path, {}).items()}
            return self._disk_cache.get(key)
