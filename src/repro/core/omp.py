"""OPT-MAT-PLAN (paper §5.3) — what to materialize while executing.

The exact problem is NP-hard (Knapsack reduction, Appendix C). Helix uses a
streaming heuristic (Algorithm 2): when a node goes *out of scope* (all
children computed/loaded; Constraint 3), materialize it iff

    2 · l_i  <  C(n_i)

where C(n_i) is the *cumulative runtime* (Def. 6): the node's own runtime
under its execution state plus the runtime of all its ancestors. Intuition:
materializing now (≈ l_i) plus loading later (≈ l_i) must beat recomputing
the chain.

We add the paper's storage budget S and two baseline policies used in the
paper's evaluation (§6.6): ALWAYS (≈ DeepDive) and NEVER (≈ KeystoneML).
A reservation that exceeds S is either refused (the old behavior) or —
with an :class:`~repro.core.eviction.Evictor` attached — admitted by
evicting the lowest-benefit-density unleased store entries first
(evict-to-admit; see eviction.py).

Beyond-paper option: amortization over expected reuse (the paper explicitly
defers this model to future work). Two sources feed it:

``horizon`` (static)
    A session-wide prior: the expected number of *future loads* of any
    materialized value. The threshold becomes (1 + 1/horizon)·l_i < C(n_i),
    so horizon=1 is exactly the paper's 2·l_i < C(n_i) (materialize now,
    load once later) and horizon→∞ approaches l_i < C(n_i). PR 2's sweep
    driver set horizon≈K ("every sibling variant will probably load this"),
    a *guess* made once for the whole sweep.

``multiplicity`` (observed, per signature)
    A callable ``sig -> expected future loads`` supplied by a driver with
    global knowledge — the session server's live cross-client
    signature-multiplicity map plus the cost model's historical reuse
    counts. When provided, the effective horizon for a node is
    ``max(horizon, multiplicity(sig))``: a signature three live clients are
    waiting on is amortized over three loads *because they are really
    there*, not because a static K said so. This supersedes the horizon≈K
    heuristic; ``horizon`` remains the floor/prior for signatures nobody
    else currently wants.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Callable, Mapping

from .dag import DAG, State
from .eviction import Evictor, benefit_density
from .locking import StorageLedger


class Policy(enum.Enum):
    """Materialization policy (§6.6): Algorithm 2 vs. the baselines."""

    OPT = "opt"        # Algorithm 2
    ALWAYS = "always"  # Helix AM
    NEVER = "never"    # Helix NM


@dataclasses.dataclass
class MatDecision:
    """One node's materialization verdict plus a human-readable reason."""

    materialize: bool
    reason: str
    # C(n_i) as evaluated for this decision (Def. 6). The executor
    # persists it with the entry (``meta.json``/index ``compute_s``) so
    # fleet eviction can rank the entry's benefit density later.
    cum_runtime: float = 0.0
    # The verdict was "materialize" but the reservation did not fit and
    # the caller asked for eviction to be deferred (``evict_inline=False``
    # — the executor decides under its scheduler lock, where eviction's
    # store I/O must not run). The caller should evict+reserve off its
    # hot path and persist on success.
    needs_eviction: bool = False
    # The node's own benefit density (eviction.py ``benefit_density``) —
    # the eviction limit for admitting it: entries at least this valuable
    # are never displaced for it. None for mandatory outputs (they must
    # persist regardless).
    benefit_density: float | None = None


def delta_fraction(plan, store) -> float:
    """Fraction of a chunked node's work an execution will actually run.

    For a node with a :class:`~repro.core.chunks.ChunkPlan`, the executor
    recomputes only the chunks whose signatures are not in the store, so
    the expected compute cost on this iteration is not the historical
    whole-value cost c_i but

        c_i^Δ  =  c_i · (missing chunks / total chunks)

    (uniform-chunk approximation; chunks are same-sized appends in the
    daily-retrain scenario). Planning with c_i^Δ is what lets OEP choose
    COMPUTE-and-splice over loading a stale whole-value entry, and makes
    OMP's (1 + 1/h)·l_i < C(n_i) price the *delta* on the cost side —
    the paper's inequality unchanged, evaluated against incremental
    reality. Returns 1.0 for an empty plan (degenerate, never emitted by
    ``compute_chunk_plans``) so a bad plan can only over-estimate cost.
    """
    if plan.n_chunks == 0:
        return 1.0
    missing = sum(1 for cs in plan.chunk_sigs if not store.has_local(cs))
    return missing / plan.n_chunks


def cumulative_runtime(dag: DAG, name: str,
                       states: Mapping[str, State],
                       runtime: Mapping[str, float]) -> float:
    """C(n_i) per Def. 6: t(n_i) + Σ_{ancestors} t(n_j), where t() is the
    realized runtime of the node under its state (0 for pruned)."""
    total = runtime.get(name, 0.0)
    for anc in dag.ancestors(name):
        total += runtime.get(anc, 0.0)
    return total


@dataclasses.dataclass
class Materializer:
    """Streaming materialization decisions under a storage budget.

    Budget accounting is atomic: the pipelined executor may reach decisions
    from several worker threads (it serializes the *order* of decisions, but
    concurrent sessions can share one Materializer), so reserve/release on
    ``used_bytes`` happens under a lock.

    Fleet mode: pass a :class:`StorageLedger` and the budget is enforced
    against the *shared on-disk* used-bytes counter instead of this
    instance's private tally — N concurrent sessions then split one
    storage budget S rather than each assuming it owns all of S. With a
    ledger, ``used_bytes`` is strictly this instance's *own outstanding
    reservations* (bytes freed by purging/evicting entries some other
    session paid for go through :meth:`credit_foreign`, which credits the
    ledger only); without one it is the whole-store tally the session
    seeds from ``store.total_bytes()``.

    Evict-to-admit: attach an :class:`~repro.core.eviction.Evictor` and a
    reservation that does not fit triggers benefit-weighted eviction of
    unleased store entries before failing (see eviction.py). ``None``
    keeps the old refuse-on-exhausted behavior.
    """

    policy: Policy = Policy.OPT
    storage_budget_bytes: float = float("inf")
    used_bytes: float = 0.0
    horizon: float = 1.0  # static prior: expected future loads (paper: 1)
    ledger: StorageLedger | None = None
    # Sweeps with pinned signature nonces make nondeterministic operators
    # equivalent across sibling variants — then they *are* reusable and
    # Algorithm 2's nondeterminism veto must be lifted.
    nondet_reusable: bool = False
    # Observed per-signature reuse (module docstring): maps a signature to
    # the expected number of future loads; the effective horizon for that
    # node is max(horizon, multiplicity(sig)). Installed by drivers with
    # global knowledge (the session server); None keeps the static prior.
    multiplicity: Callable[[str], float] | None = None
    # Evict-to-admit hook: benefit-weighted eviction of unleased store
    # entries when a reservation does not fit (None = refuse-on-exhausted).
    evictor: Evictor | None = None

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def effective_horizon(self, sig: str | None) -> float:
        """Amortization count for one node: the static ``horizon`` prior,
        lifted by the observed ``multiplicity`` of its signature."""
        h = self.horizon
        if self.multiplicity is not None and sig is not None:
            h = max(h, float(self.multiplicity(sig)))
        return max(h, 1e-9)

    def decide(self, dag: DAG, name: str,
               states: Mapping[str, State],
               runtime: Mapping[str, float],
               est_load_seconds: float,
               est_bytes: float,
               sig: str | None = None,
               evict_inline: bool = True) -> MatDecision:
        """Decide whether to materialize ``name`` as it goes out of scope
        (Algorithm 2 under the configured policy, budget, and — when
        ``sig`` is given — the observed-multiplicity amortization).

        ``evict_inline=False`` makes an over-budget verdict come back
        with ``needs_eviction`` set instead of running the evictor's
        store I/O here — for callers deciding under a hot lock (the
        pipelined executor), which then evict+reserve off that lock."""
        node = dag.nodes[name]
        # C(n_i) is only evaluated on paths that persist it (the O(ancestors)
        # walk is wasted on NEVER/nondeterministic early-outs, whose
        # decisions never reach a save).
        if node.is_output:
            # Mandatory outputs are always persisted (HML ``is_output``)
            # — no eviction limit: they may displace whatever fits.
            return self._budgeted(
                est_bytes, "mandatory output",
                cumulative_runtime(dag, name, states, runtime),
                evict_inline, density=None)
        if self.policy is Policy.NEVER:
            return MatDecision(False, "policy NM")
        if self.policy is Policy.ALWAYS:
            # Paper's DeepDive-style AM: materializes *everything*, even
            # never-reusable nondeterministic outputs (§6.6 — the wasted
            # writes are exactly why AM loses on MNIST/NLP).
            c_cum = cumulative_runtime(dag, name, states, runtime)
            return self._budgeted(
                est_bytes, "policy AM", c_cum, evict_inline,
                density=benefit_density(
                    c_cum, est_load_seconds,
                    self.effective_horizon(sig) - 1.0))
        if not node.deterministic and not self.nondet_reusable:
            return MatDecision(False, "nondeterministic: never reusable")
        # Algorithm 2 with amortization (horizon=1, no multiplicity == paper).
        c_cum = cumulative_runtime(dag, name, states, runtime)
        h = self.effective_horizon(sig)
        mult = 1.0 + 1.0 / h
        threshold = mult * est_load_seconds
        # Report the *true* threshold: the paper's 2·l only holds at an
        # effective horizon of 1 — under amortization the multiplier is
        # (1+1/h), and a debuggable ExecutionReport must say which h won.
        tag = f"{mult:.3g}·l={threshold:.3g}"
        if abs(h - 1.0) > 1e-12:
            tag += f" (h={h:.3g})"
        if threshold < c_cum:
            return self._budgeted(
                est_bytes, f"{tag} < C={c_cum:.3g}", c_cum, evict_inline,
                density=benefit_density(c_cum, est_load_seconds, h - 1.0))
        return MatDecision(False, f"{tag} >= C={c_cum:.3g}",
                           cum_runtime=c_cum)

    def _budgeted(self, est_bytes: float, reason: str,
                  cum_runtime: float = 0.0,
                  evict_inline: bool = True,
                  density: float | None = None) -> MatDecision:
        if self.try_reserve(est_bytes, evict=evict_inline,
                            benefit_density=density):
            return MatDecision(True, reason, cum_runtime=cum_runtime,
                               benefit_density=density)
        if not evict_inline and self.evictor is not None:
            # Don't run eviction's store I/O here (the caller holds a hot
            # lock): hand the verdict back with the *base* reason so the
            # caller can evict+reserve+persist off the lock.
            return MatDecision(False, reason, cum_runtime=cum_runtime,
                               needs_eviction=True,
                               benefit_density=density)
        return MatDecision(False, f"{reason}; storage budget exhausted",
                           cum_runtime=cum_runtime, benefit_density=density)

    def try_reserve(self, est_bytes: float, evict: bool = True,
                    benefit_density: float | None = None) -> bool:
        """Reserve budget for a write; also used directly by the executor's
        in-flight dedupe when it force-persists a value other sessions are
        waiting on (that save bypasses Algorithm 2 but not the budget).

        With an :attr:`evictor` attached, a reservation that does not fit
        triggers benefit-weighted eviction of unleased store entries
        (evict-to-admit) and is retried once; without one — or with
        ``evict=False`` (callers on a hot lock) — exhausted means
        refused. ``benefit_density`` is the incoming write's own density
        (see eviction.py): entries at least that valuable are never
        evicted for it (None = evict whatever fits, e.g. mandatory
        outputs)."""
        if self._reserve_once(est_bytes):
            return True
        if not evict or self.evictor is None:
            return False
        scope_exhausted = getattr(self.ledger, "scope_exhausted", None)
        if scope_exhausted is not None and scope_exhausted(est_bytes):
            # Tenant-scoped ledger refused on the tenant's *own* quota:
            # eviction frees fleet bytes, never quota room, so evicting
            # (other tenants') entries could not make this reservation
            # succeed. Refuse without touching the store — a
            # quota-exhausted tenant degrades to not-materializing, it
            # never displaces a neighbor's cache.
            return False
        used = (self.ledger.used if self.ledger is not None
                else lambda: self.used_bytes)
        self.evictor.evict_to_fit(est_bytes, self.storage_budget_bytes,
                                  used, self.credit_foreign,
                                  limit_density=benefit_density)
        return self._reserve_once(est_bytes)

    def _reserve_once(self, est_bytes: float) -> bool:
        if self.ledger is not None:
            if not self.ledger.try_reserve(est_bytes,
                                           self.storage_budget_bytes):
                return False
            with self._lock:
                self.used_bytes += est_bytes
            return True
        with self._lock:
            if self.used_bytes + est_bytes > self.storage_budget_bytes:
                return False
            self.used_bytes += est_bytes
        return True

    def release(self, nbytes: float) -> None:
        """Credit back bytes *this instance reserved* (a failed or
        overwriting save undoing its own reservation). For bytes freed
        that were never reserved here — purging or evicting entries a
        previous session paid for — use :meth:`credit_foreign`, or the
        local reserved-by-me mirror silently clamps at 0 and goes stale
        against the ledger."""
        if self.ledger is not None:
            self.ledger.release(nbytes)
        with self._lock:
            self.used_bytes = max(0.0, self.used_bytes - nbytes)

    def credit_foreign(self, nbytes: float) -> None:
        """Credit bytes freed from the store that this instance never
        reserved (§6.6 purges of a previous session's entries, fleet
        evictions). Ledger mode: ledger-only — ``used_bytes`` tracks this
        instance's own reservations and must not absorb foreign credits.
        Without a ledger, ``used_bytes`` *is* the whole-store tally, so
        the credit lands there. A tenant-scoped ledger distinguishes the
        two credits itself (``credit_foreign`` lands fleet-side only —
        the tenant's quota meter must not absorb bytes another tenant
        reserved); a plain :class:`StorageLedger` has no such method and
        takes the credit as a release."""
        if self.ledger is not None:
            foreign = getattr(self.ledger, "credit_foreign", None)
            if foreign is not None:
                foreign(nbytes)
            else:
                self.ledger.release(nbytes)
            return
        # No ledger: used_bytes is the whole-store tally, same as release.
        self.release(nbytes)

    def reconcile(self, est_bytes: float, actual_bytes: float) -> None:
        """Adjust a reservation made from the pre-save host-array estimate
        to the actual on-disk size once the write lands (npy/pickle
        overhead, ``os.path.getsize`` reality). Without this the shared
        ledger drifts from ``.fleet`` reality over long sweeps. The top-up
        direction is unconditional — the bytes are already on disk."""
        delta = float(actual_bytes) - float(est_bytes)
        if delta == 0:
            return
        if self.ledger is not None:
            self.ledger.adjust(delta)
        with self._lock:
            self.used_bytes = max(0.0, self.used_bytes + delta)
