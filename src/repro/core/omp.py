"""OPT-MAT-PLAN (paper §5.3) — what to materialize while executing.

The exact problem is NP-hard (Knapsack reduction, Appendix C). Helix uses a
streaming heuristic (Algorithm 2): when a node goes *out of scope* (all
children computed/loaded; Constraint 3), materialize it iff

    2 · l_i  <  C(n_i)

where C(n_i) is the *cumulative runtime* (Def. 6): the node's own runtime
under its execution state plus the runtime of all its ancestors. Intuition:
materializing now (≈ l_i) plus loading later (≈ l_i) must beat recomputing
the chain.

We add the paper's storage budget S (skip materialization that would exceed
it) and two baseline policies used in the paper's evaluation (§6.6):
ALWAYS (≈ DeepDive) and NEVER (≈ KeystoneML).

Beyond-paper option: amortization over expected reuse (the paper explicitly
defers this model to future work). Two sources feed it:

``horizon`` (static)
    A session-wide prior: the expected number of *future loads* of any
    materialized value. The threshold becomes (1 + 1/horizon)·l_i < C(n_i),
    so horizon=1 is exactly the paper's 2·l_i < C(n_i) (materialize now,
    load once later) and horizon→∞ approaches l_i < C(n_i). PR 2's sweep
    driver set horizon≈K ("every sibling variant will probably load this"),
    a *guess* made once for the whole sweep.

``multiplicity`` (observed, per signature)
    A callable ``sig -> expected future loads`` supplied by a driver with
    global knowledge — the session server's live cross-client
    signature-multiplicity map plus the cost model's historical reuse
    counts. When provided, the effective horizon for a node is
    ``max(horizon, multiplicity(sig))``: a signature three live clients are
    waiting on is amortized over three loads *because they are really
    there*, not because a static K said so. This supersedes the horizon≈K
    heuristic; ``horizon`` remains the floor/prior for signatures nobody
    else currently wants.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Callable, Mapping

from .dag import DAG, State
from .locking import StorageLedger


class Policy(enum.Enum):
    """Materialization policy (§6.6): Algorithm 2 vs. the baselines."""

    OPT = "opt"        # Algorithm 2
    ALWAYS = "always"  # Helix AM
    NEVER = "never"    # Helix NM


@dataclasses.dataclass
class MatDecision:
    """One node's materialization verdict plus a human-readable reason."""

    materialize: bool
    reason: str


def cumulative_runtime(dag: DAG, name: str,
                       states: Mapping[str, State],
                       runtime: Mapping[str, float]) -> float:
    """C(n_i) per Def. 6: t(n_i) + Σ_{ancestors} t(n_j), where t() is the
    realized runtime of the node under its state (0 for pruned)."""
    total = runtime.get(name, 0.0)
    for anc in dag.ancestors(name):
        total += runtime.get(anc, 0.0)
    return total


@dataclasses.dataclass
class Materializer:
    """Streaming materialization decisions under a storage budget.

    Budget accounting is atomic: the pipelined executor may reach decisions
    from several worker threads (it serializes the *order* of decisions, but
    concurrent sessions can share one Materializer), so reserve/release on
    ``used_bytes`` happens under a lock.

    Fleet mode: pass a :class:`StorageLedger` and the budget is enforced
    against the *shared on-disk* used-bytes counter instead of this
    instance's private tally — N concurrent sessions then split one
    storage budget S rather than each assuming it owns all of S.
    ``used_bytes`` remains a local mirror of what this instance reserved.
    """

    policy: Policy = Policy.OPT
    storage_budget_bytes: float = float("inf")
    used_bytes: float = 0.0
    horizon: float = 1.0  # static prior: expected future loads (paper: 1)
    ledger: StorageLedger | None = None
    # Sweeps with pinned signature nonces make nondeterministic operators
    # equivalent across sibling variants — then they *are* reusable and
    # Algorithm 2's nondeterminism veto must be lifted.
    nondet_reusable: bool = False
    # Observed per-signature reuse (module docstring): maps a signature to
    # the expected number of future loads; the effective horizon for that
    # node is max(horizon, multiplicity(sig)). Installed by drivers with
    # global knowledge (the session server); None keeps the static prior.
    multiplicity: Callable[[str], float] | None = None

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def effective_horizon(self, sig: str | None) -> float:
        """Amortization count for one node: the static ``horizon`` prior,
        lifted by the observed ``multiplicity`` of its signature."""
        h = self.horizon
        if self.multiplicity is not None and sig is not None:
            h = max(h, float(self.multiplicity(sig)))
        return max(h, 1e-9)

    def decide(self, dag: DAG, name: str,
               states: Mapping[str, State],
               runtime: Mapping[str, float],
               est_load_seconds: float,
               est_bytes: float,
               sig: str | None = None) -> MatDecision:
        """Decide whether to materialize ``name`` as it goes out of scope
        (Algorithm 2 under the configured policy, budget, and — when
        ``sig`` is given — the observed-multiplicity amortization)."""
        node = dag.nodes[name]
        if node.is_output:
            # Mandatory outputs are always persisted (HML ``is_output``).
            return self._budgeted(est_bytes, "mandatory output")
        if self.policy is Policy.NEVER:
            return MatDecision(False, "policy NM")
        if self.policy is Policy.ALWAYS:
            # Paper's DeepDive-style AM: materializes *everything*, even
            # never-reusable nondeterministic outputs (§6.6 — the wasted
            # writes are exactly why AM loses on MNIST/NLP).
            return self._budgeted(est_bytes, "policy AM")
        if not node.deterministic and not self.nondet_reusable:
            return MatDecision(False, "nondeterministic: never reusable")
        # Algorithm 2 with amortization (horizon=1, no multiplicity == paper).
        c_cum = cumulative_runtime(dag, name, states, runtime)
        threshold = (1.0 + 1.0 / self.effective_horizon(sig)) \
            * est_load_seconds
        if threshold < c_cum:
            return self._budgeted(
                est_bytes, f"2·l={threshold:.3g} < C={c_cum:.3g}")
        return MatDecision(False,
                           f"2·l={threshold:.3g} >= C={c_cum:.3g}")

    def _budgeted(self, est_bytes: float, reason: str) -> MatDecision:
        if self.try_reserve(est_bytes):
            return MatDecision(True, reason)
        return MatDecision(False, f"{reason}; storage budget exhausted")

    def try_reserve(self, est_bytes: float) -> bool:
        """Reserve budget for a write; also used directly by the executor's
        in-flight dedupe when it force-persists a value other sessions are
        waiting on (that save bypasses Algorithm 2 but not the budget)."""
        if self.ledger is not None:
            if not self.ledger.try_reserve(est_bytes,
                                           self.storage_budget_bytes):
                return False
            with self._lock:
                self.used_bytes += est_bytes
            return True
        with self._lock:
            if self.used_bytes + est_bytes > self.storage_budget_bytes:
                return False
            self.used_bytes += est_bytes
        return True

    def release(self, nbytes: float) -> None:
        """Credit back storage freed by purging stale materializations."""
        if self.ledger is not None:
            self.ledger.release(nbytes)
        with self._lock:
            self.used_bytes = max(0.0, self.used_bytes - nbytes)
