"""OPT-EXEC-PLAN (paper §5.2, Algorithm 1) — optimal reuse planning.

Given per-node compute cost ``c_i``, load cost ``l_i`` (``None`` when no
equivalent materialization exists, i.e. l_i = ∞), and the set of *original*
(changed/new) nodes, assign each node a state in {COMPUTE, LOAD, PRUNE}
minimizing total runtime

    T(W, s) = Σ_i  1[s_i = C]·c_i + 1[s_i = L]·l_i

subject to
  * Constraint 1 — original nodes must be computed,
  * Constraint 2 — a computed node's parents must not be pruned,
  * mandatory outputs must not be pruned.

The paper reduces this to the Project-Selection Problem: per node, project
``a_i`` (profit −l_i; "don't prune") and ``b_i`` (profit l_i − c_i; "and
compute"), with prerequisites b_i→a_i and b_j→a_i for every DAG edge
(n_i parent of n_j). PSP is solved exactly by min-cut / max-flow; we use
Dinic's algorithm (graphs here have O(|N|) projects, O(|E|) prerequisites —
milliseconds even for thousands of operators).

Costs are converted to integer microseconds so the flow network is exact
(Python bigints: no overflow, no float drift).
"""
from __future__ import annotations

import itertools
from typing import Iterable, Mapping

from .dag import DAG, State, validate_states

_US = 1_000_000  # seconds → integer microseconds


class _Dinic:
    """Max-flow (Dinic). Integer capacities."""

    def __init__(self, n: int):
        self.n = n
        self.adj: list[list[list[int]]] = [[] for _ in range(n)]
        # edge = [to, cap, index_of_reverse_in_adj[to]]

    def add_edge(self, u: int, v: int, cap: int) -> None:
        self.adj[u].append([v, cap, len(self.adj[v])])
        self.adj[v].append([u, 0, len(self.adj[u]) - 1])

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = [s]
        while q:
            nq = []
            for u in q:
                for e in self.adj[u]:
                    v, cap, _ = e
                    if cap > 0 and self.level[v] < 0:
                        self.level[v] = self.level[u] + 1
                        nq.append(v)
            q = nq
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: int) -> int:
        if u == t:
            return f
        while self.it[u] < len(self.adj[u]):
            e = self.adj[u][self.it[u]]
            v, cap, rev = e
            if cap > 0 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, cap))
                if d > 0:
                    e[1] -= d
                    self.adj[v][rev][1] += d
                    return d
            self.it[u] += 1
        return 0

    def max_flow(self, s: int, t: int) -> int:
        flow = 0
        INF = 1 << 62
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._dfs(s, t, INF)
                if f == 0:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> set[int]:
        """Nodes reachable from s in the residual graph (source side)."""
        seen = {s}
        stack = [s]
        while stack:
            u = stack.pop()
            for v, cap, _ in self.adj[u]:
                if cap > 0 and v not in seen:
                    seen.add(v)
                    stack.append(v)
        return seen


def plan(dag: DAG,
         compute_cost: Mapping[str, float],
         load_cost: Mapping[str, float | None],
         original: Iterable[str]) -> dict[str, State]:
    """Solve OPT-EXEC-PLAN exactly. Returns ``{name: State}``.

    ``load_cost[name] is None`` ⇔ no equivalent materialization (l=∞).
    ``original`` nodes are forced to COMPUTE (Constraint 1).
    Nodes flagged ``is_output`` are forced non-PRUNE.

    Precondition (as in the paper, where slicing runs first): every node is
    an output or an ancestor of one. On such DAGs the l=∞/c=−ε encoding of
    Constraint 1 provably forces original nodes to COMPUTE, because an
    original node's descendants are all original (recursive signatures) down
    to a mandatory output.
    """
    original = set(original)
    names = dag.topological()
    # --- integer cost model -------------------------------------------------
    finite: list[int] = []
    for n in names:
        finite.append(max(0, int(round(compute_cost[n] * _US))))
        lc = load_cost.get(n)
        if lc is not None:
            finite.append(max(0, int(round(lc * _US))))
    INF_COST = sum(finite) + 1_000_000          # "∞" load cost
    BONUS = (INF_COST + 1) * (len(names) + 2)   # must-not-prune forcing bonus
    EPS = 1                                     # original-compute tiebreaker

    c: dict[str, int] = {}
    l: dict[str, int] = {}
    bonus: dict[str, int] = {}
    for n in names:
        node = dag.nodes[n]
        if n in original:
            # Paper Appendix B: l=∞, c=−ε makes COMPUTE the unique optimum.
            c[n] = -EPS
            l[n] = INF_COST
        else:
            c[n] = max(0, int(round(compute_cost[n] * _US)))
            lc = load_cost.get(n)
            l[n] = INF_COST if lc is None else max(0, int(round(lc * _US)))
        bonus[n] = BONUS if node.is_output else 0

    # --- PSP → min-cut -------------------------------------------------------
    # project ids: a_i = 2k, b_i = 2k+1
    idx = {n: i for i, n in enumerate(names)}
    NP_ = 2 * len(names)
    S, T = NP_, NP_ + 1
    g = _Dinic(NP_ + 2)
    total_pos = 0
    INF_EDGE = 1 << 61

    def add_project(pid: int, profit: int) -> None:
        nonlocal total_pos
        if profit > 0:
            g.add_edge(S, pid, profit)
            total_pos += profit
        elif profit < 0:
            g.add_edge(pid, T, -profit)

    for n in names:
        a, b = 2 * idx[n], 2 * idx[n] + 1
        add_project(a, -l[n] + bonus[n])
        add_project(b, l[n] - c[n])
        g.add_edge(b, a, INF_EDGE)  # b_i requires a_i
        for p in dag.nodes[n].parents:
            g.add_edge(b, 2 * idx[p], INF_EDGE)  # b_child requires a_parent

    g.max_flow(S, T)
    side = g.min_cut_side(S)

    states: dict[str, State] = {}
    for n in names:
        a, b = 2 * idx[n], 2 * idx[n] + 1
        if a in side and b in side:
            states[n] = State.COMPUTE
        elif a in side:
            states[n] = State.LOAD
        else:
            states[n] = State.PRUNE

    # --- sanity (Theorem 2 guarantees these; cheap to assert) ---------------
    validate_states(dag, states)
    for n in original:
        if states[n] is not State.COMPUTE and _reachable_from_needed(dag, n, states):
            raise AssertionError(f"Constraint 1 violated for original node {n}")
    return states


def _reachable_from_needed(dag: DAG, n: str, states: dict[str, State]) -> bool:
    # An original node may legitimately be PRUNEd only if nothing non-pruned
    # depends on it and it is not an output (the slicing pass normally removes
    # such nodes before planning).
    if dag.nodes[n].is_output:
        return True
    return any(states[ch] is State.COMPUTE for ch in dag.children(n))


def plan_runtime(dag: DAG,
                 states: Mapping[str, State],
                 compute_cost: Mapping[str, float],
                 load_cost: Mapping[str, float | None]) -> float:
    """T(W, s) with the *real* costs (Eq. 1)."""
    t = 0.0
    for n in dag.topological():
        s = states[n]
        if s is State.COMPUTE:
            t += compute_cost[n]
        elif s is State.LOAD:
            lc = load_cost.get(n)
            assert lc is not None, f"loaded {n} without materialization"
            t += lc
    return t


def brute_force_plan(dag: DAG,
                     compute_cost: Mapping[str, float],
                     load_cost: Mapping[str, float | None],
                     original: Iterable[str]) -> tuple[dict[str, State], float]:
    """Exhaustive optimal plan for small *sliced* DAGs (oracle for Thm. 2).

    Applies Constraint 1 strictly: original ⇒ COMPUTE (the paper's wording).
    """
    original = set(original)
    names = dag.topological()
    best: tuple[float, dict[str, State]] | None = None
    choices = []
    for n in names:
        if n in original:
            opts = [State.COMPUTE]  # Constraint 1, strict
        else:
            opts = [State.COMPUTE, State.PRUNE]
            if load_cost.get(n) is not None:
                opts.append(State.LOAD)
        if dag.nodes[n].is_output:
            opts = [o for o in opts if o is not State.PRUNE]
        choices.append(opts)
    for combo in itertools.product(*choices):
        states = dict(zip(names, combo))
        try:
            validate_states(dag, states)
        except ValueError:
            continue
        t = plan_runtime(dag, states, compute_cost, load_cost)
        if best is None or t < best[0] - 1e-12:
            best = (t, states)
    assert best is not None
    return best[1], best[0]
