"""Remote materialization tier — fleet-wide sharing across hosts.

The PR 2–4 fleet machinery (flock compute leases, shared ledger, benefit
eviction) stops at one filesystem. This module adds a second storage tier
behind the same signature-keyed API so *hosts* share materializations:

* :class:`ObjectStore` — the narrow backend contract (put / get / list /
  delete / conditional-put), deliberately S3/GCS-shaped so a cloud bucket
  adapter is a ~40-line class. :class:`FsObjectStore` is the reference
  implementation: a shared mounted directory standing in for the bucket,
  with ``os.replace`` for atomic whole-object puts and a hard-link trick
  for the conditional put.
* :class:`RemoteStore` — the tier itself: entries live under
  ``entries/<sig>/<file>`` with a ``.complete`` marker uploaded *last*
  (the commit point — readers that don't see the marker don't see the
  entry, so a crashed upload is invisible, never torn). The local
  :class:`~repro.core.store.Store` treats it as a write-through /
  read-through cache (upload after local publish, fetch on local miss).
* **TTL leases** — ``flock`` has no cross-host analogue, so remote
  compute leases, read pins, and waiter markers are *lease objects*:
  small JSONs acquired by conditional-put, renewed by a heartbeat
  thread, and considered released the moment their ``expires`` stamp
  passes. Expiry is the crash-release story: a dead host's leases
  evaporate after one TTL instead of wedging the fleet. The worst case
  of a lease race (two hosts both observe an expired lease and race the
  takeover) is one duplicate compute — never corruption, because entry
  publication is idempotent (same signature ⇒ same value) and committed
  atomically by the marker.
* **Budget + eviction** — the remote tier has its *own* byte budget,
  independent of any host's local cache budget. Uploads that do not fit
  evict the lowest-benefit remote entries first (same
  ``(C/l)·(1+reuse)`` density as eviction.py, ranked from the metadata
  each ``.complete`` marker carries) — but never an entry with a live
  remote lease or read pin, and never for an upload less valuable than
  the candidates (the local evictor's limit-density rule, transposed).
* **Error classification + recovery** — backend errors are two kinds.
  *Transient* ones (:class:`TransientBackendError`: throttles, 5xx,
  connection resets — an adapter raises it for anything worth retrying)
  are retried in place with exponential backoff + jitter and never
  degrade the tier unless retries exhaust. Anything else (*permanent*
  for the purposes of this window: auth failures, dead mounts,
  exhausted retries) marks the tier degraded: every caller sees
  "remote absent" and the host keeps working local-only. Degradation
  is a cool-down that *re-probes*: after the window a single cheap
  health probe runs before the tier is declared usable again, and a
  failing probe re-degrades with an escalating (capped) window — so a
  dead backend costs one probe per window, not one failed real
  operation per caller (see docs/operations.md, failure modes).

Clock caveat: TTL expiry compares the *reader's* clock against the
*writer's* ``expires`` stamp, so lease TTLs must comfortably exceed
worst-case clock skew plus heartbeat jitter (see docs/operations.md for
tuning guidance; the default TTL is 60 s with renewal every TTL/3).
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import socket
import threading
import time
import uuid
from typing import Any

# Objects smaller than this are read/written whole with no streaming —
# everything here qualifies except entry leaves, which are still small
# enough (host-memory materializations) that whole-object I/O is fine.
_LEASE_PREFIX = "leases/"
_ENTRY_PREFIX = "entries/"
_MARKER = ".complete"
# Key probed (a cheap existence check) by the post-degradation health
# re-probe; it never needs to exist — the probe only asks whether the
# backend *answers*.
_HEALTH_KEY = "health/probe"


class TransientBackendError(OSError):
    """A backend failure worth retrying in place (throttle, 5xx,
    connection reset). :class:`RemoteStore` retries these with
    exponential backoff + jitter instead of degrading the tier;
    adapters over real object stores should raise it for any error
    their SDK classifies as retryable. Every other ``OSError`` is
    treated as permanent for the current degradation window."""


class ObjectStore:
    """Minimal object-store contract the remote tier speaks.

    Five operations, all S3/GCS-expressible: ``put`` (atomic
    whole-object visibility), ``get``, ``list`` (prefix scan),
    ``delete``, and ``put_if_absent`` (conditional put — S3
    ``If-None-Match:*`` / GCS ``ifGenerationMatch=0``). ``exists`` has a
    default implementation via ``get`` but backends should override it
    with a HEAD-style probe. Implementations raise ``OSError`` on
    backend failure; :class:`RemoteStore` converts that into local-only
    degradation.
    """

    def put(self, key: str, data: bytes) -> None:
        """Store ``data`` under ``key``, replacing any existing object."""
        raise NotImplementedError

    def get(self, key: str) -> bytes | None:
        """Return the object's bytes, or None when the key is absent."""
        raise NotImplementedError

    def list(self, prefix: str) -> list[str]:
        """All keys starting with ``prefix`` (sorted)."""
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        """Remove ``key``; returns False when it was already absent."""
        raise NotImplementedError

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Atomically create ``key`` iff it does not exist (the
        conditional put every lease acquisition builds on). Returns
        False when the key is already present."""
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        """Cheap presence probe (default: a full ``get``)."""
        return self.get(key) is not None

    def mtime(self, key: str) -> float | None:
        """Last-modified epoch seconds, or None when the backend cannot
        say (then age-gated maintenance like ``gc_orphans`` must skip
        the object). S3/GCS adapters return the object's LastModified."""
        return None


class FsObjectStore(ObjectStore):
    """Filesystem-backed reference backend (a shared mount as bucket).

    Keys map to files under ``root`` (``/`` separators become
    directories). ``put`` stages a sibling temp file and ``os.replace``s
    it in, so readers only ever see whole objects — the same atomic-put
    semantics a real object store gives. ``put_if_absent`` writes the
    temp file and ``os.link``s it to the target: the link fails with
    ``EEXIST`` when the key exists, and on success the full content
    appears atomically (an ``O_EXCL`` create would expose a torn,
    partially written lease to concurrent readers).
    """

    def __init__(self, root: str):
        """Create the backend over ``root`` (created if missing)."""
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        # Keys are repo-internal (signatures + fixed prefixes); reject
        # anything that could escape the root.
        if key.startswith(("/", "../")) or "/../" in key:
            raise ValueError(f"invalid object key {key!r}")
        return os.path.join(self.root, *key.split("/"))

    def _tmp(self, path: str) -> str:
        return (f"{path}.tmp-{os.getpid()}-{threading.get_ident()}"
                f"-{uuid.uuid4().hex[:8]}")

    def put(self, key: str, data: bytes) -> None:
        """Atomic whole-object put (temp file + ``os.replace``)."""
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = self._tmp(path)
        try:
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, key: str) -> bytes | None:
        """Whole-object read; None when absent."""
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except (FileNotFoundError, NotADirectoryError, IsADirectoryError):
            return None

    def list(self, prefix: str) -> list[str]:
        """Prefix scan over the tree rooted at the prefix's directory."""
        # Walk the deepest existing directory the prefix names, then
        # filter — mirrors an object store's flat prefix listing.
        base_dir = os.path.dirname(self._path(prefix + "x"))
        out: list[str] = []
        for dirpath, _dirs, files in os.walk(base_dir):
            for name in files:
                if ".tmp-" in name:
                    continue
                full = os.path.join(dirpath, name)
                key = os.path.relpath(full, self.root).replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> bool:
        """Unlink the object; False when it was already gone."""
        try:
            os.unlink(self._path(key))
            return True
        except (FileNotFoundError, NotADirectoryError):
            return False

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Conditional put via hard link (atomic, full-content)."""
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = self._tmp(path)
        with open(tmp, "wb") as f:
            f.write(data)
        try:
            os.link(tmp, path)
            return True
        except FileExistsError:
            return False
        finally:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def exists(self, key: str) -> bool:
        """stat-based presence probe (the HEAD request analogue)."""
        return os.path.isfile(self._path(key))

    def mtime(self, key: str) -> float | None:
        """File modification time (None when the key is absent)."""
        try:
            return os.stat(self._path(key)).st_mtime
        except OSError:
            return None


class _RetryingStore(ObjectStore):
    """Transparent transient-error retry decorator over a backend.

    Retries :class:`TransientBackendError` with exponential backoff +
    jitter, up to ``max_retries`` extra attempts, then re-raises (the
    caller's degradation handling takes over). Non-transient errors
    pass straight through. Jitter decorrelates N hosts hammering a
    throttled backend in lockstep.

    Retry caveat (shared with every at-least-once client): a request
    that *succeeded* backend-side but whose response was lost is
    retried. All tier operations tolerate that — puts are idempotent
    whole-object writes, deletes return False, and a retried
    ``put_if_absent`` that loses to its own first attempt reports
    "already present", which the lease protocol treats as "someone
    holds it" and resolves via the TTL.
    """

    def __init__(self, inner: ObjectStore, stats: "RemoteStats",
                 max_retries: int = 3, backoff: float = 0.05,
                 backoff_cap: float = 2.0):
        """Wrap ``inner``; retry counts accumulate on ``stats``."""
        self.inner = inner
        self.stats = stats
        self.max_retries = max(0, int(max_retries))
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self._rng = random.Random()   # jitter only — no determinism need

    def _call(self, op: str, *args):
        delay = self.backoff
        attempt = 0
        while True:
            try:
                return getattr(self.inner, op)(*args)
            except TransientBackendError:
                if attempt >= self.max_retries:
                    raise
                attempt += 1
                self.stats.n_retries += 1
                # Full jitter on an exponential schedule.
                time.sleep(delay * (0.5 + self._rng.random()))
                delay = min(delay * 2.0, self.backoff_cap)

    def put(self, key: str, data: bytes) -> None:
        """Retrying ``put``."""
        return self._call("put", key, data)

    def get(self, key: str) -> bytes | None:
        """Retrying ``get``."""
        return self._call("get", key)

    def list(self, prefix: str) -> list[str]:
        """Retrying ``list``."""
        return self._call("list", prefix)

    def delete(self, key: str) -> bool:
        """Retrying ``delete``."""
        return self._call("delete", key)

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Retrying conditional put (see class docstring's caveat)."""
        return self._call("put_if_absent", key, data)

    def exists(self, key: str) -> bool:
        """Retrying presence probe."""
        return self._call("exists", key)

    def mtime(self, key: str) -> float | None:
        """Retrying mtime probe."""
        return self._call("mtime", key)


@dataclasses.dataclass
class RemoteStats:
    """Counters for one remote tier handle's lifetime."""

    n_uploads: int = 0          # entries committed remotely by this host
    n_upload_refused: int = 0   # uploads dropped (budget unfreeable)
    n_fetches: int = 0          # entries fetched on local miss
    n_evicted: int = 0          # remote entries this host evicted
    bytes_evicted: int = 0      # their recorded bytes
    n_veto_protected: int = 0   # eviction candidates with live lease/pin
    n_errors: int = 0           # backend OSErrors (→ degradation windows)
    n_retries: int = 0          # transient-error retries (backoff layer)
    n_recoveries: int = 0       # successful post-degradation re-probes

    def snapshot(self) -> dict:
        """JSON-safe copy (server status / benchmark reporting)."""
        return dataclasses.asdict(self)


class RemoteLease:
    """A held TTL lease object (compute lease, read pin, or waiter).

    Renewed by the owning :class:`RemoteStore`'s heartbeat thread while
    held; :meth:`release` deletes the object. ``lost`` flips to True if
    a renewal finds the object taken over (our TTL expired — e.g. a long
    GC pause); the holder's work stays correct (publication is
    idempotent) but it no longer excludes other hosts.
    """

    def __init__(self, remote: "RemoteStore", key: str, kind: str):
        self._remote = remote
        self.key = key
        self.kind = kind
        self.lost = False
        self._released = False

    def release(self) -> None:
        """Delete the lease object and stop renewing it (idempotent)."""
        if self._released:
            return
        self._released = True
        self._remote._drop_lease(self)

    def __enter__(self) -> "RemoteLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class RemoteStore:
    """The shared cross-host materialization tier over an ObjectStore.

    One instance per host process (it owns that host's heartbeat thread
    and lease identity); many instances — across hosts — share one
    backend. See the module docstring for the protocol; the local
    :class:`~repro.core.store.Store` is the only intended caller of the
    entry/lease methods (pass ``remote=`` to its constructor).

    ``budget_bytes`` bounds the remote tier independently of any local
    cache budget; ``lease_ttl`` is the crash-release horizon (renewals
    every ``lease_ttl / 3`` while held; ``heartbeats=False`` disables
    renewal — for tests that simulate a crashed holder).
    """

    def __init__(self, objects: ObjectStore, *,
                 budget_bytes: float = float("inf"),
                 lease_ttl: float = 60.0,
                 heartbeats: bool = True,
                 degrade_seconds: float = 30.0,
                 degrade_max_seconds: float | None = None,
                 max_retries: int = 3,
                 retry_backoff: float = 0.05,
                 owner: str | None = None,
                 faults: Any | None = None):
        """Open a per-host handle on the shared tier (see class doc).

        ``max_retries`` / ``retry_backoff`` tune the transient-error
        retry layer (attempts beyond the first, and its initial backoff
        — exponential with jitter). ``degrade_seconds`` is the first
        degradation window after a permanent error; consecutive failed
        re-probes double it up to ``degrade_max_seconds`` (default
        8 × ``degrade_seconds``).

        ``faults`` (tests only) is a :class:`~repro.core.faults
        .FaultPlan` consulted at the named crash points of the
        publish/lease/heartbeat paths — ``upload:begin``,
        ``upload:before_marker`` (between "value uploaded" and "marker
        uploaded"), ``upload:after_marker``, ``lease:acquired``,
        ``lease:before_release``, ``delete:after_marker`` — and before
        each heartbeat renewal (:meth:`FaultPlan.drop_heartbeat`).
        """
        self.stats = RemoteStats()
        self.objects: ObjectStore = _RetryingStore(
            objects, self.stats, max_retries=max_retries,
            backoff=retry_backoff)
        self.budget_bytes = float(budget_bytes)
        self.lease_ttl = float(lease_ttl)
        self.heartbeats = bool(heartbeats)
        self.degrade_seconds = float(degrade_seconds)
        self.degrade_max_seconds = (float(degrade_max_seconds)
                                    if degrade_max_seconds is not None
                                    else 8.0 * self.degrade_seconds)
        self.owner = owner or (f"{socket.gethostname()}-{os.getpid()}"
                               f"-{uuid.uuid4().hex[:8]}")
        self._faults = faults
        self._lock = threading.Lock()
        self._held: dict[str, RemoteLease] = {}
        self._hb_thread: threading.Thread | None = None
        self._hb_stop = threading.Event()
        self._degraded_until = 0.0
        self._degrade_streak = 0        # consecutive windows, resets on probe
        self._probe_pending = False     # degraded at least once; must re-probe
        self._probe_lock = threading.Lock()
        self._closed = False
        # Marker metadata cache: sig -> (stamp, meta | None). Presence
        # probes and rankings hit this instead of the backend; negatives
        # expire fast (a sibling may publish any moment), positives
        # slower (they only go stale through remote eviction, which the
        # fetch path detects and heals by invalidating).
        self._marker_cache: dict[str, tuple[float, dict | None]] = {}
        self._pos_ttl = 15.0
        self._neg_ttl = 2.0
        # Tier byte-total cache: (monotonic stamp, total). A full
        # recount is one list + one get per marker — O(entries) backend
        # round-trips — so budgeted uploads must not pay it every time;
        # own uploads/deletes adjust the cached number in place.
        self._bytes_cache: tuple[float, int] | None = None
        self._bytes_ttl = 10.0

    # -- degradation / recovery --------------------------------------------
    def available(self) -> bool:
        """Is the tier currently usable (not in a degradation window)?

        After a degradation window passes, the first caller runs a
        cheap health probe against the backend before the tier is
        declared usable again (the *re-probe and recover* path): a
        failing probe re-degrades with an escalating window, so a dead
        backend costs one probe per window instead of a failed real
        operation per caller."""
        if self._closed or time.monotonic() < self._degraded_until:
            return False
        if not self._probe_pending:
            return True
        return self._reprobe()

    def _reprobe(self) -> bool:
        """One health probe after a degradation window (single-flight:
        concurrent callers treat the tier as still-degraded while one
        probes). True iff the backend answered and the tier recovered."""
        if not self._probe_lock.acquire(blocking=False):
            return False
        try:
            if not self._probe_pending:      # a racer already recovered us
                return True
            try:
                self.objects.exists(_HEALTH_KEY)
            except OSError as e:
                self._degrade(e)
                return False
            self._probe_pending = False
            self._degrade_streak = 0
            self.stats.n_recoveries += 1
            return True
        finally:
            self._probe_lock.release()

    def _degrade(self, exc: BaseException) -> None:
        self.stats.n_errors += 1
        self._degrade_streak += 1
        window = min(
            self.degrade_seconds * (2.0 ** (self._degrade_streak - 1)),
            self.degrade_max_seconds)
        self._degraded_until = time.monotonic() + window
        self._probe_pending = True

    def _crash_point(self, name: str) -> None:
        """Fire an armed test crash point (no-op without a fault plan)."""
        if self._faults is not None:
            self._faults.crash_point(name)

    # -- lease objects -----------------------------------------------------
    def _lease_key(self, sig: str) -> str:
        return f"{_LEASE_PREFIX}{sig}.lease"

    def _lease_blob(self, kind: str) -> bytes:
        return json.dumps({"owner": self.owner, "kind": kind,
                           "expires": time.time() + self.lease_ttl}
                          ).encode()

    def _read_obj(self, key: str) -> dict | None:
        raw = self.objects.get(key)
        if raw is None:
            return None
        try:
            obj = json.loads(raw)
            return obj if isinstance(obj, dict) else None
        except (ValueError, UnicodeDecodeError):
            return None

    @staticmethod
    def _live(obj: dict | None) -> bool:
        if obj is None:
            return False
        try:
            return float(obj.get("expires", 0.0)) >= time.time()
        except (TypeError, ValueError):
            return False

    def _track(self, lease: RemoteLease) -> RemoteLease:
        with self._lock:
            self._held[lease.key] = lease
            if (self.heartbeats and (self._hb_thread is None
                                     or not self._hb_thread.is_alive())):
                self._hb_stop.clear()
                self._hb_thread = threading.Thread(
                    target=self._heartbeat_loop, name="helix-remote-hb",
                    daemon=True)
                self._hb_thread.start()
        return lease

    def _drop_lease(self, lease: RemoteLease) -> None:
        with self._lock:
            self._held.pop(lease.key, None)
        if lease.lost:
            return  # not ours anymore: deleting would break the taker
        self._crash_point("lease:before_release")
        try:
            cur = self._read_obj(lease.key)
            if cur is not None and cur.get("owner") == self.owner:
                self.objects.delete(lease.key)
        except OSError as e:
            self._degrade(e)   # expiry will release it for us

    def _heartbeat_loop(self) -> None:
        interval = max(self.lease_ttl / 3.0, 0.02)
        while not self._hb_stop.wait(interval):
            with self._lock:
                if not self._held:
                    # Idle exit decided UNDER the lock, clearing the
                    # thread ref in the same critical section — so a
                    # _track racing this exit either sees _held non-empty
                    # here (we keep running) or sees _hb_thread None and
                    # spawns a fresh thread. Deciding outside the lock
                    # would strand a just-acquired lease unrenewed until
                    # it silently expired one TTL later.
                    self._hb_thread = None
                    return
                held = list(self._held.values())
            if (self._faults is not None
                    and self._faults.drop_heartbeat()):
                continue   # injected GC pause: skip this renewal round
            for lease in held:
                if lease.lost or lease._released:
                    continue
                try:
                    cur = self._read_obj(lease.key)
                    if cur is not None and cur.get("owner") != self.owner:
                        lease.lost = True   # expired under us; taken over
                        with self._lock:
                            self._held.pop(lease.key, None)
                        continue
                    self.objects.put(lease.key, self._lease_blob(lease.kind))
                except OSError as e:
                    self._degrade(e)  # keep trying: expiry is the backstop

    def acquire_compute(self, sig: str) -> RemoteLease | None:
        """Take the fleet-wide (cross-host) compute lease for ``sig``.

        Conditional-put acquisition; an expired lease (dead holder) is
        taken over by delete + retry. Returns None when another live
        holder exists *or* the tier is degraded — the local store then
        proceeds local-only, which at worst duplicates one compute.
        """
        if not self.available():
            return None
        key = self._lease_key(sig)
        try:
            for _ in range(2):
                if self.objects.put_if_absent(key,
                                              self._lease_blob("compute")):
                    # Crash point: the lease object exists but the
                    # holder dies before tracking/heartbeating it — the
                    # canonical crashed-holder scenario (released by TTL
                    # expiry + takeover, never by this process).
                    self._crash_point("lease:acquired")
                    return self._track(RemoteLease(self, key, "compute"))
                cur = self._read_obj(key)
                if self._live(cur):
                    return None
                # Stale (holder crashed / heartbeat stopped): reclaim.
                # Two hosts may race this delete+put; exactly one wins
                # the conditional put, the other re-reads a live lease.
                self.objects.delete(key)
            return None
        except OSError as e:
            self._degrade(e)
            return None

    def lease_live(self, sig: str, ours: bool = True) -> bool:
        """Is a compute lease on ``sig`` currently live? With
        ``ours=False``, a lease this handle owns doesn't count."""
        if not self.available():
            return False
        try:
            cur = self._read_obj(self._lease_key(sig))
        except OSError as e:
            self._degrade(e)
            return False
        if not self._live(cur):
            return False
        return ours or cur.get("owner") != self.owner

    def acquire_pin(self, sig: str) -> RemoteLease | None:
        """Pin ``sig`` against *remote* eviction (TTL read pin).

        Any number of pins coexist (each is its own object); they block
        remote eviction, not remote reads. None when degraded."""
        if not self.available():
            return None
        key = f"{_LEASE_PREFIX}{sig}.pin-{uuid.uuid4().hex}"
        try:
            self.objects.put(key, self._lease_blob("pin"))
        except OSError as e:
            self._degrade(e)
            return None
        return self._track(RemoteLease(self, key, "pin"))

    def register_waiter(self, sig: str) -> RemoteLease | None:
        """Register this host as waiting on ``sig``'s compute lease, so
        the holder force-persists the result (see Store.wait_compute).
        TTL-scoped like every lease object; None when degraded."""
        if not self.available():
            return None
        key = f"{_LEASE_PREFIX}{sig}.w-{uuid.uuid4().hex}"
        try:
            self.objects.put(key, self._lease_blob("waiter"))
        except OSError as e:
            self._degrade(e)
            return None
        return self._track(RemoteLease(self, key, "waiter"))

    def _live_objects(self, prefix: str, reap: bool = True) -> int:
        """Count live lease objects under ``prefix``, best-effort
        deleting expired ones (the TTL janitor — every counter doubles
        as cleanup, so dead hosts' leases don't accumulate)."""
        n = 0
        for key in self.objects.list(prefix):
            obj = self._read_obj(key)
            if self._live(obj):
                n += 1
            elif reap:
                try:
                    self.objects.delete(key)
                except OSError:
                    pass
        return n

    def count_waiters(self, sig: str) -> int:
        """Live cross-host waiter markers for ``sig``."""
        if not self.available():
            return 0
        try:
            return self._live_objects(f"{_LEASE_PREFIX}{sig}.w-")
        except OSError as e:
            self._degrade(e)
            return 0

    def pinned(self, sig: str) -> bool:
        """Any live read pin on ``sig``?"""
        if not self.available():
            return False
        try:
            return self._live_objects(f"{_LEASE_PREFIX}{sig}.pin-") > 0
        except OSError as e:
            self._degrade(e)
            return False

    def protected(self, sig: str) -> bool:
        """Eviction veto: live compute lease, read pin, or waiter on
        ``sig``. Remote eviction must never delete a protected entry —
        some host is mid-plan or mid-compute on it right now."""
        return (self.lease_live(sig) or self.pinned(sig)
                or self.count_waiters(sig) > 0)

    def lease_counts(self) -> dict:
        """Live lease-object census: ``{"compute", "pins", "waiters"}``
        (the observability surface docs/operations.md points at)."""
        out = {"compute": 0, "pins": 0, "waiters": 0}
        if not self.available():
            return out
        try:
            now = time.time()
            for key in self.objects.list(_LEASE_PREFIX):
                obj = self._read_obj(key)
                if obj is None:
                    continue
                try:
                    live = float(obj.get("expires", 0.0)) >= now
                except (TypeError, ValueError):
                    live = False
                if not live:
                    continue
                kind = obj.get("kind")
                if kind == "compute":
                    out["compute"] += 1
                elif kind == "pin":
                    out["pins"] += 1
                elif kind == "waiter":
                    out["waiters"] += 1
        except OSError as e:
            self._degrade(e)
        return out

    # -- entries -----------------------------------------------------------
    def _marker_key(self, sig: str) -> str:
        return f"{_ENTRY_PREFIX}{sig}/{_MARKER}"

    def _invalidate(self, sig: str) -> None:
        with self._lock:
            self._marker_cache.pop(sig, None)

    def marker_meta(self, sig: str, fresh: bool = False) -> dict | None:
        """The entry's commit-marker metadata (name/nbytes/benefit
        stats), or None when the entry is not committed remotely.
        Cached (positives ~15 s, negatives ~2 s); ``fresh`` bypasses."""
        if not self.available():
            return None
        now = time.monotonic()
        if not fresh:
            with self._lock:
                hit = self._marker_cache.get(sig)
            if hit is not None:
                stamp, meta = hit
                ttl = self._pos_ttl if meta is not None else self._neg_ttl
                if now - stamp < ttl:
                    return meta
        try:
            meta = self._read_obj(self._marker_key(sig))
        except OSError as e:
            self._degrade(e)
            return None
        with self._lock:
            self._marker_cache[sig] = (now, meta)
        return meta

    def exists(self, sig: str) -> bool:
        """Is ``sig`` committed in the remote tier?"""
        return self.marker_meta(sig) is not None

    def entries(self) -> dict[str, dict]:
        """Committed remote entries by signature (marker metadata)."""
        out: dict[str, dict] = {}
        if not self.available():
            return out
        try:
            for key in self.objects.list(_ENTRY_PREFIX):
                if not key.endswith("/" + _MARKER):
                    continue
                sig = key[len(_ENTRY_PREFIX):-(len(_MARKER) + 1)]
                meta = self._read_obj(key)
                if meta is not None:
                    out[sig] = meta
        except OSError as e:
            self._degrade(e)
        return out

    def _bytes_adjust(self, delta: int) -> None:
        with self._lock:
            if self._bytes_cache is not None:
                stamp, total = self._bytes_cache
                self._bytes_cache = (stamp, max(0, total + delta))

    def total_bytes(self, fresh: bool = False) -> int:
        """Sum of committed remote entries' recorded bytes.

        Served from a short-lived cache adjusted by this handle's own
        uploads/deletes (a recount is O(entries) backend reads — the
        budget check on every upload must not pay that); ``fresh``
        forces the recount. Siblings' concurrent uploads can make the
        cached number stale by up to the TTL — the budget is enforced
        approximately across hosts either way (there is no fleet
        ledger object; see docs/operations.md)."""
        now = time.monotonic()
        if not fresh:
            with self._lock:
                if (self._bytes_cache is not None
                        and now - self._bytes_cache[0] < self._bytes_ttl):
                    return self._bytes_cache[1]
        total = sum(int(m.get("nbytes", 0) or 0)
                    for m in self.entries().values())
        with self._lock:
            self._bytes_cache = (now, total)
        return total

    def upload(self, sig: str, local_dir: str, meta: dict) -> bool:
        """Write-through one locally published entry (idempotent).

        Reads the entry's files from ``local_dir`` (a concurrent local
        eviction aborts the upload harmlessly — uncommitted remote
        objects are invisible), uploads them, and commits by putting the
        ``.complete`` marker last. The marker carries the benefit
        metadata remote eviction ranks on. Over-budget uploads evict
        lowest-benefit unprotected remote entries first; if the deficit
        cannot be freed the upload is refused (local-only entry).
        """
        if not self.available():
            return False
        try:
            if self.objects.exists(self._marker_key(sig)):
                return True   # some host already committed it
            self._crash_point("upload:begin")
            nbytes = int(meta.get("nbytes", 0) or 0)
            if self.budget_bytes != float("inf"):
                from .eviction import benefit_density  # local: no cycle
                deficit = self.total_bytes() + nbytes - self.budget_bytes
                # The upload's own density is the eviction limit: never
                # delete remote entries at least this valuable to admit
                # it (the local evictor's limit rule, transposed).
                # Entries without cost metadata score 0 and may evict
                # nothing — a worthless upload never displaces anything.
                own = benefit_density(
                    float(meta.get("compute_s", 0) or 0),
                    float(meta.get("load_s_est", 0) or 0)
                    or max(nbytes, 1) / 500e6, 0.0)
                if deficit > 0 and \
                        self.evict_to_fit(deficit,
                                          limit_density=own) < deficit:
                    self.stats.n_upload_refused += 1
                    return False
            try:
                names = [n for n in os.listdir(local_dir)
                         if n != _MARKER and ".tmp-" not in n]
            except OSError:
                return False   # entry evicted locally mid-upload
            for name in names:
                try:
                    with open(os.path.join(local_dir, name), "rb") as f:
                        data = f.read()
                except OSError:
                    return False   # local eviction raced us: abort
                self.objects.put(f"{_ENTRY_PREFIX}{sig}/{name}", data)
            # Crash point: every data object uploaded, marker not yet —
            # the torn-publish window the commit protocol exists for.
            # A crash here leaves only invisible orphans (gc_orphans
            # reclaims them); readers never see a partial entry.
            self._crash_point("upload:before_marker")
            marker = {k: meta.get(k) for k in
                      ("name", "nbytes", "created", "compute_s",
                       "load_s_est") if k in meta}
            marker["files"] = names
            marker["uploaded_by"] = self.owner
            marker["uploaded_at"] = time.time()
            self.objects.put(self._marker_key(sig),
                             json.dumps(marker).encode())
            self._crash_point("upload:after_marker")
            self._invalidate(sig)
            self._bytes_adjust(nbytes)
            self.stats.n_uploads += 1
            return True
        except OSError as e:
            self._degrade(e)
            return False

    def fetch(self, sig: str, dest_dir: str) -> dict | None:
        """Read-through: download entry ``sig``'s files into
        ``dest_dir``. Returns the entry's ``meta.json`` dict, or None
        when the entry is absent/evicted-mid-fetch (then ``dest_dir`` is
        left incomplete and the caller discards it)."""
        if not self.available():
            return None
        try:
            marker = self.marker_meta(sig, fresh=True)
            if marker is None:
                return None
            names = marker.get("files") or [
                k[len(f"{_ENTRY_PREFIX}{sig}/"):]
                for k in self.objects.list(f"{_ENTRY_PREFIX}{sig}/")
                if not k.endswith("/" + _MARKER)]
            os.makedirs(dest_dir, exist_ok=True)
            meta: dict | None = None
            for name in names:
                data = self.objects.get(f"{_ENTRY_PREFIX}{sig}/{name}")
                if data is None:       # evicted mid-fetch
                    self._invalidate(sig)
                    return None
                if name == "meta.json":
                    try:
                        meta = json.loads(data)
                    except ValueError:
                        return None
                with open(os.path.join(dest_dir, name), "wb") as f:
                    f.write(data)
            if meta is None:
                return None
            self.stats.n_fetches += 1
            return meta
        except OSError as e:
            self._degrade(e)
            return None

    def delete_entry(self, sig: str, respect_leases: bool = True) -> int:
        """Remove a remote entry; returns its recorded bytes (0 if
        absent or — with ``respect_leases`` — protected by a live
        lease/pin/waiter). The marker is deleted *first* (atomic
        un-publish); data objects follow. A crash in between leaves
        invisible orphans for :meth:`gc_orphans`."""
        if not self.available():
            return 0
        try:
            if respect_leases and self.protected(sig):
                self.stats.n_veto_protected += 1
                return 0
            marker = self.marker_meta(sig, fresh=True)
            if marker is None:
                return 0
            if not self.objects.delete(self._marker_key(sig)):
                return 0   # another host's eviction won the race
            self._invalidate(sig)
            # Crash point: un-published (marker gone) but data objects
            # still present — the interrupted-delete orphan scenario.
            self._crash_point("delete:after_marker")
            for key in self.objects.list(f"{_ENTRY_PREFIX}{sig}/"):
                self.objects.delete(key)
            freed = int(marker.get("nbytes", 0) or 0)
            self._bytes_adjust(-freed)
            return freed
        except OSError as e:
            self._degrade(e)
            return 0

    def evict_to_fit(self, need_bytes: float,
                     limit_density: float | None = None) -> int:
        """Free remote bytes until ``need_bytes`` fit the tier budget.

        Same shape as the local :class:`~repro.core.eviction.Evictor`:
        rank committed entries ascending by benefit density
        ``(C/l)·(1+reuse)`` from the marker metadata (remote markers
        carry no load counts, so density reduces to ``C/l`` with
        upload-time LRU tie-break), skip protected entries, delete
        until the deficit is covered. ``limit_density`` is the incoming
        upload's own density: candidates at or above it are never
        evicted — ascending order means the loop can stop there.
        Returns bytes freed."""
        from .eviction import benefit_density   # local import: no cycle

        freed = 0
        scored = []
        for sig, m in self.entries().items():
            nbytes = max(float(m.get("nbytes", 0) or 0), 1.0)
            load_s = float(m.get("load_s_est", 0) or 0) or nbytes / 500e6
            cost_s = float(m.get("compute_s", 0) or 0)
            scored.append((benefit_density(cost_s, load_s, 0.0),
                           float(m.get("uploaded_at", 0.0) or 0.0),
                           sig, nbytes))
        scored.sort()
        for density, _age, sig, _nbytes in scored:
            if freed >= need_bytes:
                break
            if limit_density is not None and density >= limit_density:
                break   # every remaining candidate is at least as good
            got = self.delete_entry(sig)   # protected entries return 0
            if got > 0:
                self.stats.n_evicted += 1
                self.stats.bytes_evicted += got
                freed += got
        return freed

    def gc_orphans(self, min_age_seconds: float = 3600.0) -> int:
        """Delete entry data objects with no commit marker (crashed
        uploads / interrupted deletes). Only objects provably older than
        ``min_age_seconds`` are touched — async uploads run *after* the
        compute lease is released, so a lease check alone cannot tell an
        in-flight upload from a crashed one; age can, as long as
        ``min_age_seconds`` comfortably exceeds any plausible upload
        duration. Objects whose backend reports no modification time are
        left alone (conservative). Returns the objects removed."""
        if not self.available():
            return 0
        removed = 0
        now = time.time()
        try:
            committed: set[str] = set()
            orphans: dict[str, list[str]] = {}
            for key in self.objects.list(_ENTRY_PREFIX):
                sig = key[len(_ENTRY_PREFIX):].split("/", 1)[0]
                if key.endswith("/" + _MARKER):
                    committed.add(sig)
                else:
                    orphans.setdefault(sig, []).append(key)
            for sig, keys in orphans.items():
                if sig in committed or self.lease_live(sig):
                    continue   # committed, or a compute is in flight
                for key in keys:
                    age = self.objects.mtime(key)
                    if age is None or now - age < min_age_seconds:
                        continue   # unknown or young: maybe mid-upload
                    if self.objects.delete(key):
                        removed += 1
        except OSError as e:
            self._degrade(e)
        return removed

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Release held leases and stop the heartbeat thread."""
        self._hb_stop.set()
        with self._lock:
            held = list(self._held.values())
        for lease in held:
            lease.release()
        t = self._hb_thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)
        self._closed = True

    def __enter__(self) -> "RemoteStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def as_remote_store(remote: "RemoteStore | ObjectStore | str | None",
                    **kwargs: Any) -> RemoteStore | None:
    """Coerce a remote-tier spec into a :class:`RemoteStore`.

    Accepts an existing :class:`RemoteStore` (returned as-is — the
    caller owns its lifecycle), an :class:`ObjectStore` backend, or a
    filesystem path (the :class:`FsObjectStore` reference deployment:
    a shared mount). ``kwargs`` (budget/TTL/…) apply only when a new
    :class:`RemoteStore` is constructed here."""
    if remote is None or isinstance(remote, RemoteStore):
        return remote
    if isinstance(remote, ObjectStore):
        return RemoteStore(remote, **kwargs)
    if isinstance(remote, str):
        return RemoteStore(FsObjectStore(remote), **kwargs)
    raise TypeError(f"cannot build a remote tier from {type(remote)!r}")
