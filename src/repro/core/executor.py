"""Execution engine (paper §2.1 component 3 + §5.3 streaming discipline).

Executes a planned DAG in topological order:

* LOAD nodes read their value from the store (optionally placing array
  leaves directly onto the current mesh with a caller-supplied sharding —
  the elastic-restart path).
* COMPUTE nodes call ``node.fn(*parent_values)``; jax arrays in the result
  are blocked on so measured runtimes are honest.
* PRUNE nodes are skipped entirely.

Out-of-scope detection (Def. 5 / Constraint 3): when the last non-pruned
child of a node has been produced, the node immediately gets a
materialization decision from the :class:`Materializer` and is then evicted
from the in-memory cache (the paper's eager cache pruning, transposed here to
freeing host/HBM memory). Mandatory outputs are kept and returned.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import jax

from .dag import DAG, State
from .omp import Materializer
from .store import Store, tree_nbytes


@dataclasses.dataclass
class ExecutionReport:
    states: dict[str, State]
    runtime: dict[str, float]            # realized per-node seconds (c or l)
    materialized: dict[str, str]         # name -> reason
    skipped_mat: dict[str, str]          # name -> reason
    mat_seconds: float                   # total time spent writing (sync path)
    total_seconds: float                 # wall clock of execute()
    outputs: dict[str, Any]

    @property
    def n_computed(self) -> int:
        return sum(1 for s in self.states.values() if s is State.COMPUTE)

    @property
    def n_loaded(self) -> int:
        return sum(1 for s in self.states.values() if s is State.LOAD)

    @property
    def n_pruned(self) -> int:
        return sum(1 for s in self.states.values() if s is State.PRUNE)


def _block(value: Any) -> Any:
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()
    return value


def execute(dag: DAG,
            sigs: Mapping[str, str],
            states: Mapping[str, State],
            store: Store,
            materializer: Materializer,
            load_shardings: Mapping[str, Callable] | None = None,
            async_materialization: bool = False) -> ExecutionReport:
    t_start = time.perf_counter()
    cache: dict[str, Any] = {}
    runtime: dict[str, float] = {}
    materialized: dict[str, str] = {}
    skipped: dict[str, str] = {}
    mat_seconds = 0.0
    pending_threads = []
    load_shardings = load_shardings or {}

    # Remaining non-pruned consumers per node (for out-of-scope detection).
    remaining = {
        name: sum(1 for ch in dag.children(name)
                  if states[ch] is State.COMPUTE)
        for name in dag.nodes
    }

    def handle_out_of_scope(name: str) -> None:
        nonlocal mat_seconds
        node = dag.nodes[name]
        if states[name] is State.PRUNE:
            return
        value = cache.get(name)
        already = store.has(sigs[name])
        if already:
            skipped[name] = "already materialized"
        else:
            est_bytes = tree_nbytes(value)
            est_load = store.est_load_seconds(est_bytes)
            decision = materializer.decide(
                dag, name, states, runtime, est_load, est_bytes)
            if decision.materialize:
                if async_materialization:
                    pending_threads.append(
                        store.save_async(sigs[name], name, value))
                else:
                    info = store.save(sigs[name], name, value)
                    mat_seconds += info.seconds
                materialized[name] = decision.reason
            else:
                skipped[name] = decision.reason
        if not node.is_output:
            cache.pop(name, None)  # eager eviction (§5.4 cache pruning)

    for name in dag.topological():
        state = states[name]
        node = dag.nodes[name]
        if state is State.PRUNE:
            continue
        if state is State.LOAD:
            value, secs = store.load(sigs[name],
                                     sharding_for_leaf=load_shardings.get(name))
            _block(value)
        else:  # COMPUTE
            args = [cache[p] for p in node.parents]
            t0 = time.perf_counter()
            value = _block(node.fn(*args))
            secs = time.perf_counter() - t0
        cache[name] = value
        runtime[name] = secs
        # Out-of-scope bookkeeping: this node consumed its parents…
        if state is State.COMPUTE:
            for p in node.parents:
                remaining[p] -= 1
                if remaining[p] == 0:
                    handle_out_of_scope(p)
        # …and may itself already have no live consumers.
        if remaining[name] == 0:
            handle_out_of_scope(name)

    for th in pending_threads:
        th.join()

    outputs = {n: cache[n] for n in dag.outputs() if n in cache}
    return ExecutionReport(
        states=dict(states), runtime=runtime, materialized=materialized,
        skipped_mat=skipped, mat_seconds=mat_seconds,
        total_seconds=time.perf_counter() - t_start, outputs=outputs)
