"""Execution engine (paper §2.1 component 3 + §5.3 streaming discipline).

Executes a planned DAG with a **ready-set scheduler**: a pool of
``max_workers`` worker threads repeatedly pops the lowest-topological-index
node whose dependencies are all resolved, so independent branches of the
sliced DAG run concurrently while the paper's semantics are preserved:

* LOAD nodes are pure store I/O with no in-DAG dependencies, so they are
  *prefetched* as soon as execution starts — bounded by ``prefetch_depth``
  (the maximum number of loaded-but-unconsumed values resident at once, so
  host memory stays bounded). When the whole pool would otherwise sit idle,
  the lowest-index gated load is admitted anyway (starvation guard), which
  makes the scheduler deadlock-free even when a consumer needs more than
  ``prefetch_depth`` loads resident at once.
* COMPUTE nodes call ``node.fn(*parent_values)`` once every parent value is
  in the cache; jax arrays in the result are blocked on *inside the worker
  measuring that node* so realized per-node runtimes stay honest under
  concurrency.
* PRUNE nodes never run.

Out-of-scope detection (Def. 5 / Constraint 3): when the last non-pruned
child of a node has been produced, the node gets a materialization decision
from the :class:`Materializer` and is evicted from the in-memory cache (the
paper's eager cache pruning, transposed to freeing host/HBM memory).
Mandatory outputs are kept and returned.

**Determinism.** Materialization decisions and storage-budget accounting are
processed strictly in the out-of-scope order of the *sequential* engine
(:meth:`DAG.oos_order`), regardless of the order nodes actually finish in.
With ``max_workers=1`` the scheduler degenerates to exactly the sequential
topological sweep — same execution order, same decision order, same store
traffic — so the OEP/OMP invariants and the Theorem-1 correctness argument
carry over verbatim, and any worker count yields identical outputs and
decisions on deterministic nodes.

Materialization writes run off the critical path when
``async_materialization`` is set: values are handed to the store's dedicated
writer queue (bounded in-flight bytes) and ``mat_seconds`` aggregates the
writer's measured wall time so overhead accounting is honest in both modes.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Any, Callable, Mapping

import jax

from .dag import DAG, State
from .omp import Materializer
from .store import Store, tree_nbytes


@dataclasses.dataclass
class ExecutionReport:
    states: dict[str, State]
    runtime: dict[str, float]            # realized per-node seconds (c or l)
    materialized: dict[str, str]         # name -> reason
    skipped_mat: dict[str, str]          # name -> reason
    mat_seconds: float                   # total time spent writing (both modes)
    total_seconds: float                 # wall clock of execute()
    outputs: dict[str, Any]
    max_workers: int = 1                 # worker-pool width used
    peak_resident_loads: int = 0         # prefetch-gate high-water mark

    @property
    def n_computed(self) -> int:
        return sum(1 for s in self.states.values() if s is State.COMPUTE)

    @property
    def n_loaded(self) -> int:
        return sum(1 for s in self.states.values() if s is State.LOAD)

    @property
    def n_pruned(self) -> int:
        return sum(1 for s in self.states.values() if s is State.PRUNE)


def _block(value: Any) -> Any:
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()
    return value


class _Scheduler:
    """Shared state of one ``execute()`` call. All mutable fields are
    guarded by ``self.cv``'s lock; node work (fn calls, store I/O) runs
    outside it."""

    def __init__(self, dag: DAG, sigs, states, store, materializer,
                 load_shardings, async_materialization: bool,
                 max_workers: int, prefetch_depth: int):
        self.dag = dag
        self.sigs = sigs
        self.states = states
        self.store = store
        self.materializer = materializer
        self.load_shardings = load_shardings or {}
        self.async_mat = async_materialization
        self.max_workers = max(1, int(max_workers))
        self.prefetch_depth = max(0, int(prefetch_depth))

        self.cv = threading.Condition()
        topo = dag.topological()
        self.idx = {n: i for i, n in enumerate(topo)}
        self.indeg = dag.exec_indegree(states)
        self.runnable: list[tuple[int, str]] = [
            (self.idx[n], n) for n, d in self.indeg.items() if d == 0]
        heapq.heapify(self.runnable)
        self.n_total = len(self.indeg)
        self.n_done = 0
        self.n_inflight = 0

        # Out-of-scope bookkeeping (sequential-order decision processing).
        self.remaining = {
            name: sum(1 for ch in dag.children(name)
                      if states[ch] is State.COMPUTE)
            for name in dag.nodes
        }
        self.oos_seq = dag.oos_order(states)
        self.oos_ptr = 0
        self.oos_ready: set[str] = set()   # COMPUTE-state nodes actually OOS
        self.oos_done: set[str] = set()    # LOAD-state nodes already handled

        # Prefetch gate: loads in flight or resident-and-unconsumed.
        self.resident_loads = 0
        self.peak_resident_loads = 0

        self.cache: dict[str, Any] = {}
        self.runtime: dict[str, float] = {}
        self.materialized: dict[str, str] = {}
        self.skipped: dict[str, str] = {}
        self.mat_seconds = 0.0
        self.pending_saves: list[Any] = []
        self.error: BaseException | None = None

    # -- scheduling --------------------------------------------------------
    def _pop_runnable_locked(self) -> str | None:
        """Pop the lowest-topo-index runnable node, honoring the prefetch
        gate for LOAD nodes. Returns None when nothing can start right now.

        The gate is disabled at ``max_workers=1`` (no overlap to bound, and
        disabling it keeps the execution order exactly the sequential
        topological sweep).
        """
        gated = (self.max_workers > 1)
        blocked: list[tuple[int, str]] = []
        picked: str | None = None
        while self.runnable:
            i, name = heapq.heappop(self.runnable)
            if (gated and self.states[name] is State.LOAD
                    and self.resident_loads >= self.prefetch_depth):
                blocked.append((i, name))
                continue
            picked = name
            break
        if picked is None and blocked and self.n_inflight == 0:
            # Starvation guard: nothing can run anywhere else, so the plan
            # genuinely needs more than ``prefetch_depth`` loads resident at
            # once — admit the lowest-index one to guarantee progress.
            picked = blocked.pop(0)[1]
        for item in blocked:
            heapq.heappush(self.runnable, item)
        if picked is not None:
            self.n_inflight += 1
            if self.states[picked] is State.LOAD:
                self.resident_loads += 1
                self.peak_resident_loads = max(self.peak_resident_loads,
                                               self.resident_loads)
        return picked

    # -- node execution (outside the lock) ---------------------------------
    def _run_node(self, name: str) -> tuple[Any, float]:
        node = self.dag.nodes[name]
        if self.states[name] is State.LOAD:
            value, secs = self.store.load(
                self.sigs[name],
                sharding_for_leaf=self.load_shardings.get(name))
            _block(value)
            return value, secs
        with self.cv:
            args = [self.cache[p] for p in node.parents]
        t0 = time.perf_counter()
        value = _block(node.fn(*args))
        return value, time.perf_counter() - t0

    # -- out-of-scope / materialization ------------------------------------
    def _on_actual_oos(self, name: str) -> None:
        """Node ``name`` just lost its last live consumer (lock held)."""
        state = self.states[name]
        if state is State.PRUNE:
            return
        if state is State.LOAD:
            # Trivial decision — a loaded value is by definition already in
            # the store. Handle eagerly so the prefetch permit frees at the
            # true consumption point, not at the decision pointer.
            self.skipped[name] = "already materialized"
            if not self.dag.nodes[name].is_output:
                self.cache.pop(name, None)  # eager eviction (§5.4)
            self.resident_loads -= 1
            self.oos_done.add(name)
        else:
            self.oos_ready.add(name)

    def _advance_oos_ptr_locked(self, jobs: list[Callable[[], None]]) -> None:
        """Process materialization decisions strictly in sequential OOS
        order; slow store writes are deferred into ``jobs`` to run outside
        the lock."""
        while self.oos_ptr < len(self.oos_seq):
            name = self.oos_seq[self.oos_ptr]
            if self.states[name] is State.LOAD:
                if name not in self.oos_done:
                    break
            elif name in self.oos_ready:
                self._decide_locked(name, jobs)
            else:
                break
            self.oos_ptr += 1

    def _decide_locked(self, name: str,
                       jobs: list[Callable[[], None]]) -> None:
        node = self.dag.nodes[name]
        value = self.cache.get(name)
        if self.store.has(self.sigs[name]):
            self.skipped[name] = "already materialized"
        else:
            est_bytes = tree_nbytes(value)
            est_load = self.store.est_load_seconds(est_bytes)
            decision = self.materializer.decide(
                self.dag, name, self.states, self.runtime,
                est_load, est_bytes)
            if decision.materialize:
                self.materialized[name] = decision.reason
                sig = self.sigs[name]
                if self.async_mat:
                    def job(sig=sig, name=name, value=value):
                        self.pending_saves.append(
                            self.store.save_enqueue(sig, name, value))
                else:
                    def job(sig=sig, name=name, value=value):
                        info = self.store.save(sig, name, value)
                        with self.cv:
                            self.mat_seconds += info.seconds
                jobs.append(job)
            else:
                self.skipped[name] = decision.reason
        if not node.is_output:
            self.cache.pop(name, None)  # eager eviction (§5.4 cache pruning)

    # -- worker loop -------------------------------------------------------
    def _worker(self) -> None:
        while True:
            with self.cv:
                name = None
                while self.error is None and self.n_done < self.n_total:
                    name = self._pop_runnable_locked()
                    if name is not None:
                        break
                    self.cv.wait()
                if name is None:
                    return
            try:
                value, secs = self._run_node(name)
            except BaseException as e:  # propagate to execute()
                with self.cv:
                    self.n_inflight -= 1
                    if self.error is None:
                        self.error = e
                    self.cv.notify_all()
                return
            jobs: list[Callable[[], None]] = []
            with self.cv:
                self.cache[name] = value
                self.runtime[name] = secs
                self.n_done += 1
                self.n_inflight -= 1
                node = self.dag.nodes[name]
                if self.states[name] is State.COMPUTE:
                    for p in node.parents:
                        self.remaining[p] -= 1
                        if self.remaining[p] == 0:
                            self._on_actual_oos(p)
                for ch in self.dag.children(name):
                    if self.states[ch] is State.COMPUTE:
                        self.indeg[ch] -= 1
                        if self.indeg[ch] == 0:
                            heapq.heappush(self.runnable,
                                           (self.idx[ch], ch))
                if self.remaining[name] == 0:
                    self._on_actual_oos(name)
                self._advance_oos_ptr_locked(jobs)
                self.cv.notify_all()
            for job in jobs:
                try:
                    job()
                except BaseException as e:
                    with self.cv:
                        if self.error is None:
                            self.error = e
                        self.cv.notify_all()
                    return

    def run(self) -> None:
        n_workers = min(self.max_workers, max(self.n_total, 1))
        if n_workers <= 1:
            self._worker()
        else:
            threads = [threading.Thread(target=self._worker,
                                        name=f"helix-exec-{i}", daemon=True)
                       for i in range(n_workers)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        if self.error is not None:
            raise self.error
        # Drain the writer queue; its measured write time is this run's
        # materialization overhead (satellite of §6.6 accounting).
        for pending in self.pending_saves:
            info = pending.result()
            self.mat_seconds += info.seconds


def execute(dag: DAG,
            sigs: Mapping[str, str],
            states: Mapping[str, State],
            store: Store,
            materializer: Materializer,
            load_shardings: Mapping[str, Callable] | None = None,
            async_materialization: bool = False,
            max_workers: int = 1,
            prefetch_depth: int = 4) -> ExecutionReport:
    """Execute a planned DAG. See the module docstring for the scheduler
    model; ``max_workers=1`` reproduces the sequential paper engine
    exactly."""
    t_start = time.perf_counter()
    sched = _Scheduler(dag, sigs, states, store, materializer,
                       load_shardings, async_materialization,
                       max_workers, prefetch_depth)
    sched.run()
    outputs = {n: sched.cache[n] for n in dag.outputs() if n in sched.cache}
    return ExecutionReport(
        states=dict(states), runtime=sched.runtime,
        materialized=sched.materialized, skipped_mat=sched.skipped,
        mat_seconds=sched.mat_seconds,
        total_seconds=time.perf_counter() - t_start, outputs=outputs,
        max_workers=sched.max_workers,
        peak_resident_loads=sched.peak_resident_loads)
