"""Execution engine (paper §2.1 component 3 + §5.3 streaming discipline).

Executes a planned DAG with a **ready-set scheduler**: a pool of
``max_workers`` worker threads repeatedly pops the lowest-topological-index
node whose dependencies are all resolved, so independent branches of the
sliced DAG run concurrently while the paper's semantics are preserved:

* LOAD nodes are pure store I/O with no in-DAG dependencies, so they are
  *prefetched* as soon as execution starts — bounded by ``prefetch_depth``
  (the maximum number of loaded-but-unconsumed values resident at once, so
  host memory stays bounded). When the whole pool would otherwise sit idle,
  the lowest-index gated load is admitted anyway (starvation guard), which
  makes the scheduler deadlock-free even when a consumer needs more than
  ``prefetch_depth`` loads resident at once.
* COMPUTE nodes call ``node.fn(*parent_values)`` once every parent value is
  in the cache; jax arrays in the result are blocked on *inside the worker
  measuring that node* so realized per-node runtimes stay honest under
  concurrency.
* PRUNE nodes never run.

Out-of-scope detection (Def. 5 / Constraint 3): when the last non-pruned
child of a node has been produced, the node gets a materialization decision
from the :class:`Materializer` and is evicted from the in-memory cache (the
paper's eager cache pruning, transposed to freeing host/HBM memory).
Mandatory outputs are kept and returned.

**Determinism.** Materialization decisions and storage-budget accounting are
processed strictly in the out-of-scope order of the *sequential* engine
(:meth:`DAG.oos_order`), regardless of the order nodes actually finish in.
With ``max_workers=1`` the scheduler degenerates to exactly the sequential
topological sweep — same execution order, same decision order, same store
traffic — so the OEP/OMP invariants and the Theorem-1 correctness argument
carry over verbatim, and any worker count yields identical outputs and
decisions on deterministic nodes. One carve-out: with an evictor attached
(evict-to-admit), over-budget admissions are deferred off the scheduler
lock — at ``max_workers=1`` they still happen in decision order, but
under parallel workers admission order may interleave (the same
nondeterminism class as the fleet-shared ledger itself).

Materialization writes run off the critical path when
``async_materialization`` is set: values are handed to the store's dedicated
writer queue (bounded in-flight bytes) and ``mat_seconds`` aggregates the
writer's measured wall time so overhead accounting is honest in both modes.

**In-flight dedupe (fleet mode).** With ``dedupe_inflight`` set, a COMPUTE
node first takes the store's fleet-wide *compute lease* on its signature:

* lease acquired → compute as usual; if other sessions registered as
  waiters meanwhile, the value is force-persisted (budget permitting)
  before the lease is released, so the waiters can load it — each
  signature is computed at most once fleet-wide;
* lease held elsewhere → wait for the holder, then load its published
  result (recorded in ``ExecutionReport.deduped``; the node's realized
  runtime is the load time). If the entry was not persisted (no budget /
  holder crashed) the wait loop retries the lease and computes. Waits are
  bounded by ``dedupe_wait_seconds`` — on timeout the session computes the
  value itself (duplicate work, never a deadlock).

Dedupe introduces cross-*session* scheduling nondeterminism by design (who
computes vs. loads depends on arrival order); within a single session the
determinism guarantees above are unchanged, and the mode is off by default.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Any, Callable, Mapping

import jax

from .chunks import Chunked, tree_concat, tree_stack
from .dag import DAG, State
from .eviction import benefit_density
from .omp import Materializer, cumulative_runtime
from .store import Store, tree_nbytes


class JobCancelled(RuntimeError):
    """The execution's cancel flag fired and the run stopped between
    nodes. Raised out of :func:`execute` after the normal settle path
    (pending saves drained, reservations reconciled or released, leases
    released by their ``finally`` blocks) — the session server reports
    it as status ``cancelled``, not ``error``."""


@dataclasses.dataclass
class ExecutionReport:
    states: dict[str, State]
    runtime: dict[str, float]            # realized per-node seconds (c or l)
    materialized: dict[str, str]         # name -> reason
    skipped_mat: dict[str, str]          # name -> reason
    mat_seconds: float                   # total time spent writing (both modes)
    total_seconds: float                 # wall clock of execute()
    outputs: dict[str, Any]
    max_workers: int = 1                 # worker-pool width used
    peak_resident_loads: int = 0         # prefetch-gate high-water mark
    # COMPUTE-planned nodes whose value was in fact loaded because another
    # session computed the same signature first (in-flight dedupe).
    deduped: dict[str, str] = dataclasses.field(default_factory=dict)
    # Chunk-granular accounting (incremental recomputation, chunks.py):
    # per chunked node, how many chunks ran fn vs. spliced from cache.
    # On a pure-incremental path after an append, chunk_computed equals
    # exactly the number of appended chunks — the oracle asserts this.
    chunk_computed: dict[str, int] = dataclasses.field(default_factory=dict)
    chunk_reused: dict[str, int] = dataclasses.field(default_factory=dict)
    # Nodes the planner chose to COMPUTE although a loadable entry existed
    # (recomputing was cheaper than loading). These are deliberate
    # economics, not missed reuse — fleet accounting (SweepReport)
    # distinguishes them from coordination failures.
    chose_compute: frozenset = frozenset()

    @property
    def n_computed(self) -> int:
        return sum(1 for s in self.states.values() if s is State.COMPUTE)

    @property
    def n_loaded(self) -> int:
        return sum(1 for s in self.states.values() if s is State.LOAD)

    @property
    def n_pruned(self) -> int:
        return sum(1 for s in self.states.values() if s is State.PRUNE)


def _block(value: Any) -> Any:
    for leaf in jax.tree_util.tree_leaves(value):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()
    return value


class _Scheduler:
    """Shared state of one ``execute()`` call. All mutable fields are
    guarded by ``self.cv``'s lock; node work (fn calls, store I/O) runs
    outside it."""

    def __init__(self, dag: DAG, sigs, states, store, materializer,
                 load_shardings, async_materialization: bool,
                 max_workers: int, prefetch_depth: int,
                 dedupe_inflight: bool = False,
                 dedupe_wait_seconds: float = 120.0,
                 share_sigs: frozenset | set | None = None,
                 dedupe_skip: frozenset | set | None = None,
                 worker_pool=None,
                 cancel: threading.Event | None = None,
                 chunk_plans: Mapping | None = None):
        self.dag = dag
        self.sigs = sigs
        self.states = states
        self.store = store
        self.materializer = materializer
        self.load_shardings = load_shardings or {}
        self.async_mat = async_materialization
        self.max_workers = max(1, int(max_workers))
        self.prefetch_depth = max(0, int(prefetch_depth))
        self.dedupe = bool(dedupe_inflight)
        self.dedupe_wait_seconds = float(dedupe_wait_seconds)
        # Signatures known (by the sweep driver / session server) to be
        # wanted by sibling sessions: always persisted on lease-compute, so
        # each is computed exactly once fleet-wide even when siblings race
        # the waiter registration or arrive later. Any object supporting
        # ``in`` works — the session server passes a live view over its
        # cross-client multiplicity map so clients that arrive mid-run
        # still count.
        self.share_sigs = (share_sigs if share_sigs is not None
                           else frozenset())
        # Optional process-wide elastic worker pool (serve/pool.py): when
        # set, extra workers beyond the caller's thread are borrowed from
        # (and bounded by) the shared pool instead of spawned per-execute.
        self.worker_pool = worker_pool
        # Nodes the planner chose to COMPUTE *despite* a loadable entry
        # (load costlier than recompute): the dedupe shortcut must not
        # override that judgment by loading anyway.
        self.dedupe_skip = frozenset(dedupe_skip or ())
        # Cooperative cancellation: checked between nodes (and inside
        # lease waits). When it fires, the first worker to notice sets
        # ``self.error`` to JobCancelled and the run winds down through
        # the normal error path — leases, pins, and reservations are
        # released by the same finally/settle code an exception uses.
        self.cancel = cancel

        self.cv = threading.Condition()
        topo = dag.topological()
        self.idx = {n: i for i, n in enumerate(topo)}
        self.indeg = dag.exec_indegree(states)
        self.runnable: list[tuple[int, str]] = [
            (self.idx[n], n) for n, d in self.indeg.items() if d == 0]
        heapq.heapify(self.runnable)
        self.n_total = len(self.indeg)
        self.n_done = 0
        self.n_inflight = 0

        # Out-of-scope bookkeeping (sequential-order decision processing).
        self.remaining = {
            name: sum(1 for ch in dag.children(name)
                      if states[ch] is State.COMPUTE)
            for name in dag.nodes
        }
        self.oos_seq = dag.oos_order(states)
        self.oos_ptr = 0
        self.oos_ready: set[str] = set()   # COMPUTE-state nodes actually OOS
        self.oos_done: set[str] = set()    # LOAD-state nodes already handled

        # Prefetch gate: loads in flight or resident-and-unconsumed.
        self.resident_loads = 0
        self.peak_resident_loads = 0

        # Chunk-granular plans (chunks.py): COMPUTE nodes with a plan run
        # per-chunk — cached chunks spliced in, missing ones recomputed —
        # and *always* per-chunk even on a cold store, so results are a
        # pure function of (chunk values, plan) and the differential
        # oracle's bit-identity holds exactly.
        self.chunk_plans = dict(chunk_plans or {})
        self.chunk_computed: dict[str, int] = {}
        self.chunk_reused: dict[str, int] = {}

        self.cache: dict[str, Any] = {}
        self.runtime: dict[str, float] = {}
        self.materialized: dict[str, str] = {}
        self.skipped: dict[str, str] = {}
        self.deduped: dict[str, str] = {}
        self.mat_seconds = 0.0
        self.pending_saves: list[Any] = []
        self.error: BaseException | None = None

    # -- scheduling --------------------------------------------------------
    def _cancelled_locked(self) -> bool:
        """Between-nodes cancel check (lock held): the first worker that
        sees the flag turns it into the run's error so every worker winds
        down through the normal error path."""
        if self.cancel is None or not self.cancel.is_set():
            return False
        if self.error is None:
            self.error = JobCancelled("job cancelled between nodes")
            self.cv.notify_all()
        return True

    def _pop_runnable_locked(self) -> str | None:
        """Pop the lowest-topo-index runnable node, honoring the prefetch
        gate for LOAD nodes. Returns None when nothing can start right now.

        The gate is disabled at ``max_workers=1`` (no overlap to bound, and
        disabling it keeps the execution order exactly the sequential
        topological sweep).
        """
        gated = (self.max_workers > 1)
        blocked: list[tuple[int, str]] = []
        picked: str | None = None
        while self.runnable:
            i, name = heapq.heappop(self.runnable)
            if (gated and self.states[name] is State.LOAD
                    and self.resident_loads >= self.prefetch_depth):
                blocked.append((i, name))
                continue
            picked = name
            break
        if picked is None and blocked and self.n_inflight == 0:
            # Starvation guard: nothing can run anywhere else, so the plan
            # genuinely needs more than ``prefetch_depth`` loads resident at
            # once — admit the lowest-index one to guarantee progress.
            picked = blocked.pop(0)[1]
        for item in blocked:
            heapq.heappush(self.runnable, item)
        if picked is not None:
            self.n_inflight += 1
            if self.states[picked] is State.LOAD:
                self.resident_loads += 1
                self.peak_resident_loads = max(self.peak_resident_loads,
                                               self.resident_loads)
        return picked

    # -- node execution (outside the lock) ---------------------------------
    def _run_node(self, name: str) -> tuple[Any, float]:
        node = self.dag.nodes[name]
        if self.states[name] is State.LOAD:
            value, secs = self.store.load(
                self.sigs[name],
                sharding_for_leaf=self.load_shardings.get(name))
            _block(value)
            return value, secs
        if self.dedupe and name not in self.dedupe_skip:
            return self._run_compute_deduped(name, node)
        return self._run_compute(name, node)

    def _run_compute(self, name: str, node) -> tuple[Any, float]:
        plan = self.chunk_plans.get(name)
        with self.cv:
            raw = [self.cache[p] for p in node.parents]
        if plan is not None:
            t0 = time.perf_counter()
            value = self._run_chunked(name, node, plan, raw)
            return value, time.perf_counter() - t0
        # Opaque consumers always see the assembled (logical) value: a
        # chunked parent's partitioning is an executor-internal carrier.
        args = [v.assemble() if isinstance(v, Chunked) else v for v in raw]
        t0 = time.perf_counter()
        value = _block(node.fn(*args))
        return value, time.perf_counter() - t0

    # -- chunk-granular execution (incremental recomputation) --------------
    def _chunk_from_store(self, csig: str):
        """Load one cached chunk; ``(None, False)`` on miss (or when a
        concurrent eviction raced the presence check — then it is simply
        recomputed, same as a miss)."""
        if not self.store.has_local(csig):
            return None, False
        try:
            value, _secs = self.store.load(csig)
        except FileNotFoundError:
            return None, False
        return value, True

    def _run_chunked(self, name: str, node, plan, raw: list) -> Any:
        """Execute one node at chunk granularity per its ChunkPlan.

        Cached chunks (signature-keyed entries published by an earlier
        iteration's splice) are loaded; missing chunks run ``fn``; the
        pieces splice into a :class:`Chunked`. Per-chunk load/compute
        seconds land in the node's single realized runtime — so the cost
        model's recorded compute cost automatically reflects the *delta*,
        which is what makes OMP re-price incrementally maintained nodes
        correctly on the next iteration."""
        n_reused = n_computed = 0
        if plan.mode == "source":
            cached = [self._chunk_from_store(cs) for cs in plan.chunk_sigs]
            if all(hit for _v, hit in cached):
                chunks = tuple(v for v, _hit in cached)
                n_reused = len(chunks)
            else:
                produced = list(node.fn())
                if len(produced) != plan.n_chunks:
                    raise ValueError(
                        f"{name}: chunked source returned {len(produced)} "
                        f"chunks for {plan.n_chunks} declared descriptors")
                # Prefer cached copies where present (bit-identical by the
                # determinism contract; keeps splice I/O honest in counts).
                chunks = tuple(v if hit else _block(produced[j])
                               for j, (v, hit) in enumerate(cached))
                n_reused = sum(1 for _v, hit in cached if hit)
                n_computed = plan.n_chunks - n_reused
            value = Chunked(chunks, plan.chunk_sigs)
        elif plan.mode == "union":
            parts = dict(zip(node.parents, raw))
            chunks, csigs = [], []
            for p in node.parents:
                pv = parts[p]
                if not isinstance(pv, Chunked):
                    raise ValueError(
                        f"{name}: union parent {p!r} is not chunked")
                chunks.extend(pv.chunks)
                csigs.extend(pv.chunk_sigs)
            if tuple(csigs) != plan.chunk_sigs:
                raise ValueError(
                    f"{name}: union parents' chunk signatures do not "
                    "match the plan (parent re-chunked mid-run?)")
            n_reused = len(chunks)   # concat invokes no fn at all
            value = Chunked(tuple(chunks), plan.chunk_sigs)
        elif plan.mode in ("map", "assoc_reduce"):
            chunked = {p: v for p, v in zip(node.parents, raw)
                       if p in plan.chunked_parents}
            broadcast = {p: (v.assemble() if isinstance(v, Chunked) else v)
                         for p, v in zip(node.parents, raw)
                         if p not in plan.chunked_parents}
            pieces = []
            for j, csig in enumerate(plan.chunk_sigs):
                piece, hit = self._chunk_from_store(csig)
                if hit:
                    n_reused += 1
                else:
                    args = [chunked[p].chunks[j] if p in chunked
                            else broadcast[p] for p in node.parents]
                    piece = _block(node.fn(*args))
                    n_computed += 1
                pieces.append(piece)
            if plan.mode == "map":
                value = Chunked(tuple(pieces), plan.chunk_sigs)
            else:
                # Combine partials through fn itself, substituting the
                # stacked partials for the chunked parent
                # (fn(concat(chunks)) == fn(stack(partials))).
                args = [tree_stack(pieces) if p in chunked
                        else broadcast[p] for p in node.parents]
                final = _block(node.fn(*args))
                value = Chunked(tuple(pieces), plan.chunk_sigs,
                                "reduce", final=final)
        else:
            raise ValueError(f"{name}: unknown chunk-plan mode "
                             f"{plan.mode!r}")
        with self.cv:
            self.chunk_reused[name] = n_reused
            self.chunk_computed[name] = n_computed
        return value

    def _run_compute_deduped(self, name: str, node) -> tuple[Any, float]:
        """Fleet-wide compute-once: lease → compute (+ force-persist when
        waiters exist) | lease busy → wait, then load the holder's result."""
        sig = self.sigs[name]
        lease = None
        deadline = time.monotonic() + self.dedupe_wait_seconds
        while True:
            if self.cancel is not None and self.cancel.is_set():
                raise JobCancelled(f"cancelled while deduping {name!r}")
            if self.store.has(sig):
                try:
                    value, secs = self.store.load(
                        sig, sharding_for_leaf=self.load_shardings.get(name))
                except FileNotFoundError:
                    continue  # raced an eviction — retry
                _block(value)
                with self.cv:
                    self.deduped[name] = "computed by another session"
                return value, secs
            lease = self.store.acquire_compute(sig)
            if lease is not None:
                if (self.store.remote is not None
                        and self.store.has_fresh(sig)):
                    # Another HOST committed the entry between our
                    # (cached) presence check and the lease acquisition
                    # — release and loop to the load path; computing
                    # here would break fleet-wide compute-once.
                    lease.release()
                    continue
                break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break  # bounded wait: duplicate-compute beats deadlock
            if not self.store.wait_compute(sig, timeout=remaining,
                                           cancel=self.cancel):
                if self.cancel is not None and self.cancel.is_set():
                    raise JobCancelled(
                        f"cancelled while waiting on lease for {name!r}")
                break
            # The lease lock came free (or is only held by shared read
            # pins, which coexist with our shared wait) yet the entry is
            # still absent and exclusive acquisition failed. Back off
            # briefly so this retry loop can never busy-spin; the
            # deadline above bounds it overall.
            time.sleep(0.005)
        try:
            value, secs = self._run_compute(name, node)
            if lease is not None:
                self._share_inflight(name, sig, lease, value, secs)
            return value, secs
        finally:
            if lease is not None:
                lease.release()

    def _share_inflight(self, name: str, sig: str, lease,
                        value: Any, compute_seconds: float) -> None:
        """Persist a just-computed value for the fleet, *before* the lease
        is released (so waiters find it on wake-up). Persists when the
        signature is known-shared across sibling variants, when someone is
        registered as waiting, or when reloading is cheaper than the
        measured compute (a sibling that races the waiter registration —
        or plans later — then LOADs instead of recomputing). This bypasses
        Algorithm 2 — cross-session reuse makes the payoff certain — but
        still reserves against the (possibly fleet-shared) budget."""
        if self.store.has(sig):
            return
        n_waiting = lease.waiters()
        est_bytes = tree_nbytes(value)
        # Write decisions price the durable (disk) tier: the value is not
        # resident on any tier yet, and the waiters this persist serves
        # will read it from disk/remote, not this process's memory tier.
        est_load = self.store.est_load_seconds(est_bytes)
        if (sig not in self.share_sigs and n_waiting == 0
                and est_load >= compute_seconds):
            return  # nobody wants it and recompute is cheaper than load
        # Benefit metadata for fleet eviction: C(n) per Def. 6 — the node's
        # own measured compute plus its ancestors' realized runtimes (all
        # finished: they are its inputs). self.runtime has no entry for
        # this node yet (the worker records it after _run_node returns).
        with self.cv:
            rt = dict(self.runtime)
        rt[name] = compute_seconds
        c_cum = cumulative_runtime(self.dag, name, self.states, rt)
        # Evict-to-admit may clear space, but only of entries less
        # valuable than this one. Expected future loads: registered
        # waiters now, or the materializer's multiplicity-aware horizon
        # (known-shared signatures whose siblings have not reached the
        # waiter registration yet must not get a weaker admission limit
        # than the same signature would get on the decide path).
        expected = max(float(n_waiting),
                       self.materializer.effective_horizon(sig) - 1.0)
        density = benefit_density(c_cum, est_load, expected)
        if not self.materializer.try_reserve(est_bytes,
                                             benefit_density=density):
            return  # no budget: waiters recompute after the timeout/retry
        extra = {"compute_s": c_cum, "load_s_est": est_load}
        info = self._budgeted_save(sig, name, value, est_bytes,
                                   extra_meta=extra)
        if self.store.remote is not None:
            # Publish-before-release: a cross-host waiter wakes the
            # moment the remote TTL lease vanishes and has no view of
            # this host's local tier — the async uploader alone would
            # open a recompute window exactly where dedupe matters.
            # Synchronous write-through here keeps compute-once exact
            # fleet-wide; non-shared materializations stay async.
            self.store.upload_now(sig)
        with self.cv:
            self.mat_seconds += info.seconds
            self.materialized[name] = (
                f"in-flight dedupe: {n_waiting} waiting session(s)"
                if n_waiting else "in-flight dedupe: shared signature")

    def _budgeted_save(self, sig: str, name: str, value: Any,
                       est_bytes: float,
                       extra_meta: dict | None = None) -> Any:
        """Persist a value whose budget was already reserved, keeping the
        (possibly fleet-shared) ledger honest: the reservation is
        *reconciled* to the actual on-disk size once known (the pre-save
        host-array estimate drifts from npy/pickle reality), credited back
        entirely if the write fails, and — when the save overwrote an
        entry a concurrent session already paid for — the *replaced
        entry's* recorded bytes are credited (they are what the overwrite
        freed; crediting the new reservation instead drifts the ledger
        whenever the sizes differ)."""
        try:
            info = self.store.save(sig, name, value, extra_meta=extra_meta)
        except BaseException:
            self.materializer.release(est_bytes)
            raise
        self._settle_save(est_bytes, info)
        return info

    def _settle_save(self, est_bytes: float, info) -> None:
        """The one place for the landed-write accounting invariant:
        reconcile the estimate-based reservation to the actual on-disk
        size, and credit the *replaced* entry's recorded bytes when the
        save overwrote one (sync saves and the async drain both settle
        through here, so the ledger-drift fixes cannot diverge)."""
        self.materializer.reconcile(est_bytes, info.nbytes)
        if info.replaced:
            self.materializer.credit_foreign(info.replaced_nbytes)

    def _persist_value(self, sig: str, name: str, value: Any,
                       est_bytes: float, extra_meta: dict) -> None:
        """Hand an admitted (budget-reserved) value to the configured
        write path: the store's writer queue under async materialization
        (settled at the drain), else a settling synchronous save. One
        body for the normal and eviction-admitted branches, so their
        accounting cannot diverge."""
        if self.async_mat:
            self.pending_saves.append(
                (est_bytes, self.store.save_enqueue(
                    sig, name, value, extra_meta=extra_meta)))
        else:
            info = self._budgeted_save(sig, name, value, est_bytes,
                                       extra_meta=extra_meta)
            with self.cv:
                self.mat_seconds += info.seconds

    # -- out-of-scope / materialization ------------------------------------
    def _on_actual_oos(self, name: str) -> None:
        """Node ``name`` just lost its last live consumer (lock held)."""
        state = self.states[name]
        if state is State.PRUNE:
            return
        if state is State.LOAD:
            # Trivial decision — a loaded value is by definition already in
            # the store. Handle eagerly so the prefetch permit frees at the
            # true consumption point, not at the decision pointer.
            self.skipped[name] = "already materialized"
            if not self.dag.nodes[name].is_output:
                self.cache.pop(name, None)  # eager eviction (§5.4)
            self.resident_loads -= 1
            self.oos_done.add(name)
        else:
            self.oos_ready.add(name)

    def _advance_oos_ptr_locked(self, jobs: list[Callable[[], None]]) -> None:
        """Process materialization decisions strictly in sequential OOS
        order; slow store writes are deferred into ``jobs`` to run outside
        the lock."""
        while self.oos_ptr < len(self.oos_seq):
            name = self.oos_seq[self.oos_ptr]
            if self.states[name] is State.LOAD:
                if name not in self.oos_done:
                    break
            elif name in self.oos_ready:
                self._decide_locked(name, jobs)
            else:
                break
            self.oos_ptr += 1

    def _decide_locked(self, name: str,
                       jobs: list[Callable[[], None]]) -> None:
        node = self.dag.nodes[name]
        value = self.cache.get(name)
        if name in self.materialized:
            pass  # force-persisted by the in-flight dedupe path
        elif self.store.has(self.sigs[name]):
            self.skipped[name] = "already materialized"
        else:
            est_bytes = tree_nbytes(value)
            # Durable-tier price on purpose (no sig): Algorithm 2 is
            # deciding whether a *future* load beats a recompute, and
            # the future loader pays the disk tier — the memory tier's
            # zero-copy hit is a same-process bonus on top, not the
            # cost this write must amortize.
            est_load = self.store.est_load_seconds(est_bytes)
            # evict_inline=False: this runs under the scheduler lock, and
            # eviction is store I/O (index scan + deletes) that every
            # worker would otherwise stall behind — an over-budget
            # "materialize" verdict comes back as needs_eviction and the
            # evict+reserve+save runs as a deferred job below.
            decision = self.materializer.decide(
                self.dag, name, self.states, self.runtime,
                est_load, est_bytes, sig=self.sigs[name],
                evict_inline=False)
            # Cost metadata rides with the entry so fleet eviction can
            # rank its benefit density (C(n)/l_i) later.
            extra = {"compute_s": decision.cum_runtime,
                     "load_s_est": est_load}
            sig = self.sigs[name]
            if decision.materialize:
                self.materialized[name] = decision.reason
                jobs.append(lambda sig=sig, name=name, value=value,
                            est=est_bytes, extra=extra:
                            self._persist_value(sig, name, value, est,
                                                extra))
            elif decision.needs_eviction:
                # Evict-to-admit, off the lock. With max_workers=1 the
                # job runs immediately after this decision (sequential
                # semantics unchanged); under parallel workers deferred
                # admissions may interleave with later decisions — the
                # same nondeterminism class the fleet ledger already has
                # (budget state is shared across sessions). The decision
                # carries the node's own benefit density as the eviction
                # limit: mandatory outputs may evict whatever fits
                # (None); everything else only displaces entries *less*
                # valuable than itself.
                def job(sig=sig, name=name, value=value, est=est_bytes,
                        extra=extra, reason=decision.reason,
                        limit=decision.benefit_density):
                    if not self.materializer.try_reserve(
                            est, benefit_density=limit):
                        with self.cv:
                            self.skipped[name] = \
                                f"{reason}; storage budget exhausted"
                        return
                    with self.cv:
                        self.materialized[name] = \
                            f"{reason} (admitted by eviction)"
                    self._persist_value(sig, name, value, est, extra)
                jobs.append(job)
            else:
                self.skipped[name] = decision.reason
        if not node.is_output:
            self.cache.pop(name, None)  # eager eviction (§5.4 cache pruning)

    # -- worker loop -------------------------------------------------------
    def _worker(self) -> None:
        while True:
            with self.cv:
                name = None
                while self.error is None and self.n_done < self.n_total:
                    if self._cancelled_locked():
                        break
                    name = self._pop_runnable_locked()
                    if name is not None:
                        break
                    # The canceller only sets an Event (it has no handle
                    # on this cv), so waits must time out to notice it.
                    self.cv.wait(timeout=0.25 if self.cancel is not None
                                 else None)
                if name is None:
                    return
            try:
                value, secs = self._run_node(name)
            except BaseException as e:  # propagate to execute()
                with self.cv:
                    self.n_inflight -= 1
                    if self.error is None:
                        self.error = e
                    self.cv.notify_all()
                return
            jobs: list[Callable[[], None]] = []
            with self.cv:
                self.cache[name] = value
                self.runtime[name] = secs
                self.n_done += 1
                self.n_inflight -= 1
                node = self.dag.nodes[name]
                if self.states[name] is State.COMPUTE:
                    for p in node.parents:
                        self.remaining[p] -= 1
                        if self.remaining[p] == 0:
                            self._on_actual_oos(p)
                for ch in self.dag.children(name):
                    if self.states[ch] is State.COMPUTE:
                        self.indeg[ch] -= 1
                        if self.indeg[ch] == 0:
                            heapq.heappush(self.runnable,
                                           (self.idx[ch], ch))
                if self.remaining[name] == 0:
                    self._on_actual_oos(name)
                self._advance_oos_ptr_locked(jobs)
                self.cv.notify_all()
            # Run the whole decision batch even if one job raises: every
            # job owns a decide-time ledger reservation that it settles
            # itself (save, reconcile, or release-on-failure) — aborting
            # mid-batch would strand the remaining jobs' reservations in
            # the fleet-shared ledger permanently.
            batch_error: BaseException | None = None
            for job in jobs:
                try:
                    job()
                except BaseException as e:
                    if batch_error is None:
                        batch_error = e
            if batch_error is not None:
                with self.cv:
                    if self.error is None:
                        self.error = batch_error
                    self.cv.notify_all()
                return

    def run(self) -> None:
        n_workers = min(self.max_workers, max(self.n_total, 1))
        if n_workers <= 1:
            self._worker()
        elif self.worker_pool is not None:
            # Elastic: the calling thread always runs one worker (progress
            # is guaranteed even with the pool exhausted); up to
            # n_workers-1 extras are borrowed from the shared pool.
            self.worker_pool.run(self._worker, n_workers)
        else:
            threads = [threading.Thread(target=self._worker,
                                        name=f"helix-exec-{i}", daemon=True)
                       for i in range(n_workers)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        # Settle the writer queue *before* propagating any worker error:
        # enqueued saves' reservations live in the (possibly fleet-shared)
        # ledger and must be reconciled or released no matter how the run
        # ended — skipping them on a worker error would leak reservations
        # into .fleet/ledger.json permanently (shrinking every future
        # session's budget and triggering spurious fleet-wide evictions).
        drain_error = self._drain_pending_saves()
        if self.error is not None:
            raise self.error
        if drain_error is not None:
            raise drain_error

    def _drain_pending_saves(self) -> BaseException | None:
        """Settle every queued async save: measured write time feeds
        ``mat_seconds`` (§6.6 accounting honesty), each landed write
        reconciles its estimate-based reservation to the actual on-disk
        size, and failed writes credit the reservation back. Never aborts
        early; returns the first error instead of raising so the caller
        can settle everything first."""
        drain_error: BaseException | None = None
        for est, pending in self.pending_saves:
            try:
                info = pending.result()
            except BaseException as e:
                self.materializer.release(est)
                if drain_error is None:
                    drain_error = e
                continue
            self._settle_save(est, info)
            self.mat_seconds += info.seconds
        return drain_error


def execute(dag: DAG,
            sigs: Mapping[str, str],
            states: Mapping[str, State],
            store: Store,
            materializer: Materializer,
            load_shardings: Mapping[str, Callable] | None = None,
            async_materialization: bool = False,
            max_workers: int = 1,
            prefetch_depth: int = 4,
            dedupe_inflight: bool = False,
            dedupe_wait_seconds: float = 120.0,
            share_sigs: frozenset | set | None = None,
            dedupe_skip: frozenset | set | None = None,
            worker_pool=None,
            cancel: threading.Event | None = None,
            chunk_plans: Mapping | None = None) -> ExecutionReport:
    """Execute a planned DAG. See the module docstring for the scheduler
    model; ``max_workers=1`` reproduces the sequential paper engine
    exactly. ``dedupe_inflight`` enables the fleet-wide compute-once
    protocol for COMPUTE nodes (shared-store concurrent sessions);
    ``share_sigs`` marks signatures known to recur across sibling
    sessions (always persisted on lease-compute). ``worker_pool`` (a
    ``repro.serve.SharedWorkerPool``) makes the worker count elastic:
    extra workers are borrowed from one process-wide pool shared by all
    sessions instead of spawned per call. ``cancel`` (a
    ``threading.Event``) requests cooperative cancellation: workers
    check it between nodes and inside lease waits, the run stops with
    :class:`JobCancelled`, and cleanup (pending saves, reservations,
    leases) follows the same settle path any error takes.
    ``chunk_plans`` (``{name: ChunkPlan}`` from
    ``compute_chunk_signatures``) turns on chunk-granular execution for
    the planned nodes: cached chunks are spliced from the store and only
    missing ones recomputed (see chunks.py)."""
    t_start = time.perf_counter()
    sched = _Scheduler(dag, sigs, states, store, materializer,
                       load_shardings, async_materialization,
                       max_workers, prefetch_depth,
                       dedupe_inflight=dedupe_inflight,
                       dedupe_wait_seconds=dedupe_wait_seconds,
                       share_sigs=share_sigs,
                       dedupe_skip=dedupe_skip,
                       worker_pool=worker_pool,
                       cancel=cancel,
                       chunk_plans=chunk_plans)
    sched.run()
    # Outputs are always the logical values: the chunk partitioning is an
    # executor/store-internal carrier, invisible to session callers.
    outputs = {n: (v.assemble() if isinstance(v, Chunked) else v)
               for n, v in ((n, sched.cache[n]) for n in dag.outputs()
                            if n in sched.cache)}
    return ExecutionReport(
        states=dict(states), runtime=sched.runtime,
        materialized=sched.materialized, skipped_mat=sched.skipped,
        mat_seconds=sched.mat_seconds,
        total_seconds=time.perf_counter() - t_start, outputs=outputs,
        max_workers=sched.max_workers,
        peak_resident_loads=sched.peak_resident_loads,
        deduped=sched.deduped,
        chose_compute=frozenset(dedupe_skip or ()),
        chunk_computed=sched.chunk_computed,
        chunk_reused=sched.chunk_reused)
