"""Operator cost statistics (paper §5.1 "Operator Metrics").

``c_i`` (compute seconds) is measured at execution and keyed by the node's
*signature*: if a node has been run before under the same signature, the
recorded statistic is exact, which is the paper's assumption ("we would have
run the exact same operator before and recorded accurate c_i and l_i").

Beyond-paper: for *never-seen* nodes the paper has a cold-start problem (it
must compute them anyway by Constraint 1, but OMP and downstream planning
still want estimates). We allow a ``cost_hint`` (e.g. derived from a compiled
dry-run's roofline terms: max(flops/peak, bytes/bw)) as a prior.

Statistics persist to JSON so sessions survive process restarts — that is
what turns checkpoint/restart into plain Helix reuse.

Fleet mode: many sessions may share one ``costs.json`` (one workdir, N
concurrent sweep variants or processes). ``save()`` is therefore a
*merge-on-flush* transaction — under the file lock it re-reads the on-disk
blob, EWMA-blends statistics **this session actually measured** into it
(they are keyed by signature, so both sides measured the same operator;
blending smooths machine noise), unions the rest, and publishes
atomically. Values merely read from disk at init are NOT re-merged — that
would let a stale historical number partially revert a sibling's fresher
measurement. Sessions refine a shared model instead of clobbering each
other's flushes.

Observed reuse: every time a signature's value is *reused* (a planned LOAD
or an in-flight dedupe hit) the model counts it. ``reuse_count`` feeds
OMP's amortized materialization threshold (see omp.py ``multiplicity``):
a signature the fleet has historically loaded seven times is worth
materializing even when no sibling is live right now. Reuse counts are
merged additively on flush (each session contributes the events it
witnessed; they are disjoint by construction).
"""
from __future__ import annotations

import threading

from .locking import read_json, update_json

# Weight of THIS session's fresh measurement when the signature also has
# an on-disk value: recency dominates (a large gap means the environment
# changed), the old value just damps noise.
_MERGE_NEW = 0.7


class CostModel:
    """Per-signature operator statistics (compute seconds, output bytes,
    seen-set for change tracking, observed reuse counts), persisted to one
    JSON file with fleet-safe merge-on-flush semantics."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        blob = read_json(path, {})
        self.compute_s: dict[str, float] = blob.get("compute_s", {})
        self.nbytes: dict[str, float] = blob.get("nbytes", {})
        self.seen: set[str] = set(blob.get("seen", []))
        self.reuse: dict[str, float] = blob.get("reuse", {})
        # signatures recorded by THIS session since the last flush — the
        # only ones whose values save() pushes into the shared file
        self._dirty: set[str] = set()
        # reuse events witnessed here since the last flush (merged
        # additively: sessions witness disjoint events)
        self._reuse_delta: dict[str, float] = {}

    def _merge_stat(self, disk: dict[str, float], mine: dict[str, float]
                    ) -> dict[str, float]:
        out = dict(disk)
        for sig, v in mine.items():
            if sig in self._dirty:
                cur = out.get(sig)
                out[sig] = (v if cur is None
                            else (1 - _MERGE_NEW) * float(cur)
                            + _MERGE_NEW * v)
            elif sig not in out:
                # not measured here and gone from disk: keep the knowledge
                out[sig] = v
        return out

    def save(self) -> None:
        """Flush this session's fresh statistics into the shared file
        (merge-on-flush; see the module docstring) and adopt the merged
        fleet view."""
        with self._lock:
            def txn(blob):
                reuse = dict(blob.get("reuse", {}))
                for sig, delta in self._reuse_delta.items():
                    reuse[sig] = float(reuse.get(sig, 0.0)) + delta
                for sig, v in self.reuse.items():
                    reuse.setdefault(sig, v)   # keep knowledge from init
                return {
                    "compute_s": self._merge_stat(
                        blob.get("compute_s", {}), self.compute_s),
                    "nbytes": self._merge_stat(
                        blob.get("nbytes", {}), self.nbytes),
                    "seen": sorted(set(blob.get("seen", [])) | self.seen),
                    "reuse": reuse,
                }

            merged = update_json(self.path, txn, {})
            # Adopt the merged view: other sessions' statistics become
            # available to this session's next planning pass.
            self.compute_s = dict(merged["compute_s"])
            self.nbytes = dict(merged["nbytes"])
            self.seen = set(merged["seen"])
            self.reuse = dict(merged["reuse"])
            self._dirty.clear()
            self._reuse_delta.clear()

    # -- recording -------------------------------------------------------------
    def record(self, sig: str, compute_seconds: float | None = None,
               nbytes: float | None = None, reused: bool = False) -> None:
        """Record an execution observation for ``sig``. ``reused`` marks a
        reuse event (the value was loaded instead of computed).

        Holds the model lock for the whole update: the session server
        shares one CostModel across concurrent job threads, so a record
        must never interleave with a sibling's ``save()`` (whose merge
        iterates these dicts and then clears the dirty set — an unlocked
        record in that window would be silently dropped)."""
        with self._lock:
            if compute_seconds is not None:
                self.compute_s[sig] = compute_seconds
                self._dirty.add(sig)
            if nbytes is not None:
                self.nbytes[sig] = nbytes
                self._dirty.add(sig)
            if reused:
                self.reuse[sig] = self.reuse.get(sig, 0.0) + 1.0
                self._reuse_delta[sig] = \
                    self._reuse_delta.get(sig, 0.0) + 1.0
            self.seen.add(sig)

    # -- queries ---------------------------------------------------------------
    def compute_cost(self, sig: str, hint: float | None = None,
                     default: float = 1.0) -> float:
        """Estimated compute seconds for ``sig``: measured if known, else
        the caller's ``hint`` (e.g. a roofline dry-run), else ``default``."""
        if sig in self.compute_s:
            return self.compute_s[sig]
        if hint is not None:
            return hint
        return default

    def is_original(self, sig: str) -> bool:
        """Paper §4.2: has this signature never been executed before?"""
        return sig not in self.seen

    def reuse_count(self, sig: str) -> float:
        """Observed lifetime reuse events for ``sig`` (fleet-merged)."""
        return float(self.reuse.get(sig, 0.0))

    def reuse_counts(self) -> dict[str, float]:
        """One consistent snapshot of every signature's observed reuse
        count (fleet-merged at the last flush plus events witnessed here
        since). The evictor ranks a whole store against this, so it wants
        one locked copy rather than a per-signature race with a
        concurrent ``save()``'s dict swap."""
        with self._lock:
            return {sig: float(v) for sig, v in self.reuse.items()}


class TierBandwidth:
    """Per-tier EWMA load bandwidths over one store's ``.fleet/bw.json``.

    The paper's ``l_i`` was a single per-store number; with the TierStack
    (memory → disk → remote) each tier gets its own measured bandwidth
    and fixed per-access latency floor, so OMP's ``(1+1/h)·l_i < C(n_i)``
    rule can price the *cheapest reachable tier* of a signature rather
    than assuming every hit pays a disk read.

    Wraps the store's existing :class:`~repro.core.locking.SharedEwma`
    (fleet merge-on-flush). The disk tier keeps the legacy ``read`` /
    ``write`` keys — old ``bw.json`` files stay valid and the no-``sig``
    estimate is numerically identical to the pre-tier formula
    (``nbytes / (read|write|500e6) + 1e-4``). Memory and remote add
    ``mem_*`` / ``remote_*`` keys beside them in the same file.

    Floors are deliberately conservative static priors, not tuning
    knobs: ~8 GB/s for a host-RAM pointer handoff (the measured EWMA
    takes over after the first hit), 500 MB/s local disk (the historical
    default), 100 MB/s + 1 ms for an object store round-trip.
    """

    _KEYS = {"memory": ("mem_read", "mem_write"),
             "local": ("read", "write"),
             "remote": ("remote_read", "remote_write")}
    _FLOOR_BW = {"memory": 8e9, "local": 500e6, "remote": 100e6}
    _LATENCY = {"memory": 1e-6, "local": 1e-4, "remote": 1e-3}

    def __init__(self, ewma):
        self._ewma = ewma

    def observe(self, tier: str, kind: str, nbytes: float,
                seconds: float) -> None:
        """Record one measured transfer (``kind`` is "read"/"write")."""
        if nbytes <= 0 or seconds <= 0:
            return
        rk, wk = self._KEYS[tier]
        self._ewma.update(rk if kind == "read" else wk,
                          float(nbytes) / float(seconds))

    def bandwidth(self, tier: str) -> float:
        """Best available bytes/s estimate for ``tier``: measured reads,
        else measured writes, else the tier's static floor."""
        rk, wk = self._KEYS[tier]
        bw = self._ewma.get(rk) or self._ewma.get(wk)
        return float(bw) if bw else self._FLOOR_BW[tier]

    def est_load_seconds(self, tier: str, nbytes: float) -> float:
        """Estimated seconds to serve ``nbytes`` from ``tier``."""
        return float(nbytes) / self.bandwidth(tier) + self._LATENCY[tier]
