"""Operator cost statistics (paper §5.1 "Operator Metrics").

``c_i`` (compute seconds) is measured at execution and keyed by the node's
*signature*: if a node has been run before under the same signature, the
recorded statistic is exact, which is the paper's assumption ("we would have
run the exact same operator before and recorded accurate c_i and l_i").

Beyond-paper: for *never-seen* nodes the paper has a cold-start problem (it
must compute them anyway by Constraint 1, but OMP and downstream planning
still want estimates). We allow a ``cost_hint`` (e.g. derived from a compiled
dry-run's roofline terms: max(flops/peak, bytes/bw)) as a prior.

Statistics persist to JSON so sessions survive process restarts — that is
what turns checkpoint/restart into plain Helix reuse.
"""
from __future__ import annotations

import json
import os
import threading


class CostModel:
    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self.compute_s: dict[str, float] = {}
        self.nbytes: dict[str, float] = {}
        self.seen: set[str] = set()
        if os.path.exists(path):
            with open(path) as f:
                blob = json.load(f)
            self.compute_s = blob.get("compute_s", {})
            self.nbytes = blob.get("nbytes", {})
            self.seen = set(blob.get("seen", []))

    def save(self) -> None:
        with self._lock:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"compute_s": self.compute_s,
                           "nbytes": self.nbytes,
                           "seen": sorted(self.seen)}, f)
            os.replace(tmp, self.path)

    # -- recording -------------------------------------------------------------
    def record(self, sig: str, compute_seconds: float | None = None,
               nbytes: float | None = None) -> None:
        if compute_seconds is not None:
            self.compute_s[sig] = compute_seconds
        if nbytes is not None:
            self.nbytes[sig] = nbytes
        self.seen.add(sig)

    # -- queries ---------------------------------------------------------------
    def compute_cost(self, sig: str, hint: float | None = None,
                     default: float = 1.0) -> float:
        if sig in self.compute_s:
            return self.compute_s[sig]
        if hint is not None:
            return hint
        return default

    def is_original(self, sig: str) -> bool:
        return sig not in self.seen
